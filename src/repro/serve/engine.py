"""Serving engine: continuous batching over the paged (BTT-style) KV cache.

The engine runs dense/GQA decoder LMs (the transformer family) with the
paged decode path: per layer, the new token's K/V are appended to the
sequence's pages (block-table write = the lba->pba map update) and decode
attention gathers pages through the table (the Pallas kernel on TPU,
interpret/ref on CPU).

Scheduling follows the paper's transit discipline:
  * finished / preempted sequences are *eagerly* packed to the host tier
    (``deactivate``) so the HBM pool stays near-empty, exactly like Caiti's
    WBQ drain;
  * when admission would overflow the pool anyway, the new sequence's pages
    *bypass* to the host tier rather than stall a running decode;
  * a step "fsync" (``barrier``) completes all migrations before the batch
    shape changes.

This is the host-driven reference engine (layer loop in Python, pools as
per-layer arrays) — shaped for the CPU container and for tests; the mesh
path for bulk decode lowers ``lm_decode_step`` with the dense ring cache
(see launch/dryrun.py decode cells).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.metrics import Metrics
from repro.models.common import ModelConfig
from repro.models.layers import apply_norm, rope
from .kvcache import PagedCacheConfig, PagedKVCache


@dataclass
class Request:
    req_id: int
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: list[int] = field(default_factory=list)
    seq_id: int = -1
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


def _layer_params(params, i: int):
    return jax.tree.map(lambda a: a[i], params["blocks"])


class PagedLM:
    """Paged decode path for the dense transformer family."""

    def __init__(self, cfg: ModelConfig, params, cache: PagedKVCache,
                 use_kernel: bool = True) -> None:
        assert cfg.family == "dense", "paged engine serves dense LMs"
        self.cfg = cfg
        self.params = params
        self.cache = cache
        self.use_kernel = use_kernel

    def prefill(self, tokens: np.ndarray, sid: int) -> jnp.ndarray:
        """Run the prompt through the model, append K/V pages, return the
        last-token logits. tokens: (T,) one sequence."""
        cfg, p = self.cfg, self.params
        T = len(tokens)
        tok = jnp.asarray(tokens, jnp.int32)[None]
        x = jnp.take(p["embed"], tok, axis=0)
        positions = jnp.arange(T, dtype=jnp.int32)[None]
        kv_per_layer = []
        for li in range(cfg.n_layers):
            blk = _layer_params(p, li)
            xn = apply_norm(x, blk["ln1"], cfg.norm)
            q = (xn @ blk["attn"]["wq"]).reshape(1, T, cfg.n_heads, cfg.hd)
            k = (xn @ blk["attn"]["wk"]).reshape(1, T, cfg.n_kv_heads, cfg.hd)
            v = (xn @ blk["attn"]["wv"]).reshape(1, T, cfg.n_kv_heads, cfg.hd)
            if "bq" in blk["attn"]:
                q = q + blk["attn"]["bq"].reshape(1, 1, cfg.n_heads, cfg.hd)
                k = k + blk["attn"]["bk"].reshape(1, 1, cfg.n_kv_heads, cfg.hd)
                v = v + blk["attn"]["bv"].reshape(1, 1, cfg.n_kv_heads, cfg.hd)
            if cfg.pos == "rope":
                q = rope(q, positions, cfg.rope_theta)
                k = rope(k, positions, cfg.rope_theta)
            # dense causal attention for the prompt (prefill is compute-bound;
            # pages are written below for the decode phase)
            from repro.kernels.ref import flash_attention_ref
            a = flash_attention_ref(q, k, v, causal=True,
                                    window=cfg.attn_window)
            x = x + a.reshape(1, T, -1) @ blk["attn"]["wo"]
            h = apply_norm(x, blk["ln2"], cfg.norm)
            from repro.models.layers import mlp_apply
            x = x + mlp_apply(h, blk["mlp"], cfg.act)
            kv_per_layer.append((k[0], v[0]))            # (T, Hkv, hd)
        # append pages token-by-token (bulk write path)
        for t in range(T):
            self.cache.append_token(
                sid,
                [kv_per_layer[li][0][t] for li in range(cfg.n_layers)],
                [kv_per_layer[li][1][t] for li in range(cfg.n_layers)])
        x = apply_norm(x[:, -1:], p["final_norm"], cfg.norm)
        w = p["embed"].T if cfg.tie_embeddings else p["head"]
        return (x @ w).astype(jnp.float32)[0, 0]

    def decode_step(self, tokens: np.ndarray, sids: list[int],
                    positions: np.ndarray) -> jnp.ndarray:
        """One token for each running sequence. tokens: (B,), returns
        (B, V) logits."""
        cfg, p = self.cfg, self.params
        B = len(tokens)
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        pos = jnp.asarray(positions, jnp.int32)[:, None]
        x = jnp.take(p["embed"], tok, axis=0)            # (B, 1, D)
        new_kv = [[None] * cfg.n_layers for _ in range(B)]
        for li in range(cfg.n_layers):
            blk = _layer_params(p, li)
            xn = apply_norm(x, blk["ln1"], cfg.norm)
            q = (xn @ blk["attn"]["wq"]).reshape(B, 1, cfg.n_heads, cfg.hd)
            k = (xn @ blk["attn"]["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
            v = (xn @ blk["attn"]["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.hd)
            if "bq" in blk["attn"]:
                q = q + blk["attn"]["bq"].reshape(1, 1, cfg.n_heads, cfg.hd)
                k = k + blk["attn"]["bk"].reshape(1, 1, cfg.n_kv_heads, cfg.hd)
                v = v + blk["attn"]["bv"].reshape(1, 1, cfg.n_kv_heads, cfg.hd)
            if cfg.pos == "rope":
                q = rope(q, pos, cfg.rope_theta)
                k = rope(k, pos, cfg.rope_theta)
            for bi in range(B):
                new_kv[bi][li] = (k[bi, 0], v[bi, 0])
            # append THIS layer's kv before attending (token attends to self)
            if li == 0:
                for bi, sid in enumerate(sids):
                    self.cache.append_token(
                        sid, [new_kv[bi][L][0] if new_kv[bi][L] else
                              jnp.zeros((cfg.n_kv_heads, cfg.hd), cfg.dtype)
                              for L in range(cfg.n_layers)],
                        [new_kv[bi][L][1] if new_kv[bi][L] else
                         jnp.zeros((cfg.n_kv_heads, cfg.hd), cfg.dtype)
                         for L in range(cfg.n_layers)])
            else:
                # layers >0: write into the already-appended slot
                for bi, sid in enumerate(sids):
                    self._overwrite_token(sid, li, new_kv[bi][li])
            a = self.cache.attention(li, q[:, 0], sids,
                                     use_kernel=self.use_kernel)
            x = x + a.reshape(B, 1, -1) @ blk["attn"]["wo"]
            h = apply_norm(x, blk["ln2"], cfg.norm)
            from repro.models.layers import mlp_apply
            x = x + mlp_apply(h, blk["mlp"], cfg.act)
        x = apply_norm(x, p["final_norm"], cfg.norm)
        w = p["embed"].T if cfg.tie_embeddings else p["head"]
        return (x @ w).astype(jnp.float32)[:, 0]

    def _overwrite_token(self, sid: int, layer: int, kv) -> None:
        # delegated: the cache serializes the pool/table write on _tlock
        # (an unlocked write here would race the eviction-pool workers)
        self.cache.overwrite_token(sid, layer, kv)


class AsyncRequestLog:
    """Durable request log riding a striped volume's async frontend.

    Each retired request is one JSON record, appended as a chained
    ``write_multi`` through ``volume.submit`` — the write overlaps the
    next decode step instead of stalling the scheduler tick on the PMem
    round trip (the transit discipline, applied to the serving plane's
    own durability).  ``drain()`` settles every in-flight ticket and
    issues one async fsync barrier (which coalesces with any concurrent
    committer via the volume's GroupCommitter); a device error surfaces
    there as that record's per-ticket failure, not a serving-loop
    exception.

    ``volume`` is anything speaking the async surface — a
    ``StripedVolume`` or a ``repro.cluster.ClusterVolume`` (a
    replicated request log that survives node loss).  Records are
    capped at the device's ``max_atomic_write_blocks()`` so a
    multi-block append stays whole-record atomic everywhere (on a
    cluster that bound is one placement chunk — a record spanning
    chunks would commit chain by chain).

    ``registered_buffers > 0`` acquires a :class:`BufferRegistry` pool
    on the volume's engine and appends through it: each record's blocks
    are filled into pinned pool buffers and the HANDLES ride the ticket
    — the engine never snapshots the payload under its lock, and the
    buffers release back to the pool at completion (success, failure or
    cancel).  This is the same zero-copy discipline the checkpoint
    blockstore's commit path uses, extended to the serving plane's
    ``write_multi`` block lists."""

    def __init__(self, volume, *, base_lba: int = 0,
                 capacity_blocks: int | None = None,
                 tenant: str | None = None,
                 registered_buffers: int = 0) -> None:
        self.vol = volume
        self.tenant = tenant
        self.block_size = volume.block_size
        self._reg = (volume.register_buffers(registered_buffers)
                     if registered_buffers > 0
                     and hasattr(volume, "register_buffers") else None)
        self._max_rec = (volume.max_atomic_write_blocks()
                         if hasattr(volume, "max_atomic_write_blocks")
                         else None)
        self._base = base_lba
        # the log is a RING over [base_lba, base_lba + capacity): a
        # long-running serve loop wraps and overwrites its oldest
        # records instead of writing past the volume (ship records to
        # cold storage before a wrap if they must be kept forever)
        self._cap = (volume.n_lbas - base_lba if capacity_blocks is None
                     else capacity_blocks)
        assert self._cap >= 1
        self._off = 0
        self._tickets: list = []
        self.logged = 0
        self.wraps = 0
        self.errors: list[tuple[int, BaseException]] = []

    def _alloc(self, n_blocks: int) -> int:
        assert n_blocks <= self._cap, "record larger than the log ring"
        assert self._max_rec is None or n_blocks <= self._max_rec, \
            (f"record of {n_blocks} blocks exceeds the device's "
             f"whole-object-atomic bound ({self._max_rec})")
        if self._off + n_blocks > self._cap:
            self._off = 0                    # wrap: oldest records go
            self.wraps += 1
        lba = self._base + self._off
        self._off += n_blocks
        return lba

    def append(self, record: dict) -> None:
        raw = json.dumps(record).encode()
        bs = self.block_size
        payload = len(raw).to_bytes(4, "little") + raw
        blocks = [payload[i:i + bs].ljust(bs, b"\x00")
                  for i in range(0, len(payload), bs)]
        if self._reg is not None:
            # zero-copy: fill pool buffers OUTSIDE the engine lock and
            # submit the pinned handles; completion releases them
            regs = []
            for chunk in blocks:
                buf = self._reg.acquire()
                buf.data[:len(chunk)] = np.frombuffer(chunk, np.uint8)
                regs.append(buf)
            blocks = regs
        # block=True: a retirement burst deeper than the engine's
        # in-flight window waits its turn (the one stall this log
        # accepts) — a record is never silently dropped
        lba = self._alloc(len(blocks))
        if len(blocks) > 1:
            t = self.vol.submit("write_multi", lba, blocks=blocks,
                                tenant=self.tenant, block=True)
        else:
            t = self.vol.submit("write", lba, data=blocks[0],
                                tenant=self.tenant, block=True)
        self._tickets.append((lba, t))
        self.logged += 1

    def drain(self) -> int:
        """One async fsync barrier + post-barrier error collection;
        returns how many records have failed since the previous drain
        (all failures stay collected in ``errors``).

        The barrier is submitted FIRST: IO_DRAIN gates it on every
        in-flight append in-engine, so the drain pays ONE wait round
        trip instead of one per record — by the time the barrier
        completes, every append ticket is already settled and error
        collection is a ring sweep, not a sequence of waits."""
        reported = len(self.errors)
        sync = self.vol.submit("fsync", block=True)
        self.vol.wait(sync)
        tickets, self._tickets = self._tickets, []
        for lba, t in tickets:           # already DONE: consume + collect
            self.vol.wait(t)
            if t.error is not None:
                self.errors.append((lba, t.error))
        if sync.error is not None:
            raise sync.error
        return len(self.errors) - reported


class ServeEngine:
    """Continuous-batching front end."""

    def __init__(self, cfg: ModelConfig, params, *,
                 cache_cfg: PagedCacheConfig | None = None,
                 max_batch: int = 8, eos_token: int = -1,
                 use_kernel: bool = False, rng_seed: int = 0,
                 request_log: AsyncRequestLog | None = None,
                 autotune_every: int = 0,
                 pager=None, prefetch_depth: int = 2) -> None:
        self.cfg = cfg
        self.metrics = Metrics()
        # optional durable request log: retired requests are appended
        # through the volume's async frontend, overlapped with decode
        self.request_log = request_log
        # control-plane cadence: every N scheduler ticks, run one
        # autotune_step() on the request log's backing volume (no-op
        # unless the volume has a controller attached) — the serve loop
        # is the natural place for the storage control ticks to ride
        self.autotune_every = autotune_every
        self._ticks_since_tune = 0
        # optional volume-backed KV spill tier (serve.kvpager.KVPager):
        # suspended sessions' cold pages descend past the host tier onto
        # the striped volume; prefetch_depth suspended requests get
        # decode-ahead linked reads issued each tick so their resume
        # overlaps the current batch's decode
        self.prefetch_depth = prefetch_depth
        self.cache = PagedKVCache(cache_cfg or PagedCacheConfig(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.hd), metrics=self.metrics, pager=pager)
        self.lm = PagedLM(cfg, params, self.cache, use_kernel=use_kernel)
        self.max_batch = max_batch
        self.eos = eos_token
        self.queue: list[Request] = []
        self.running: list[Request] = []
        self.suspended: list[Request] = []
        self.finished: list[Request] = []
        self._rng = np.random.default_rng(rng_seed)
        self._next_id = 0

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               temperature: float = 0.0) -> Request:
        req = Request(self._next_id, list(prompt), max_new_tokens,
                      temperature, t_submit=time.perf_counter())
        self._next_id += 1
        self.queue.append(req)
        return req

    # ----------------------------------------------------------- scheduling
    def suspend(self, req: Request) -> None:
        """Preempt a running request: its pages eagerly transit out
        (host tier, then the volume once the host budget overflows);
        ``_admit`` resumes it ahead of fresh prompts."""
        self.running.remove(req)
        self.cache.deactivate(req.seq_id)
        self.suspended.append(req)
        self.metrics.bump("suspends")

    def _prefetch_ahead(self) -> None:
        """Decode-ahead restore: linked async reads for the next
        ``prefetch_depth`` suspended requests' spilled pages, issued
        BEFORE admission so the volume round trip overlaps this tick's
        decode instead of stalling activate()."""
        for req in self.suspended[:self.prefetch_depth]:
            self.cache.prefetch(req.seq_id)

    def _admit(self) -> None:
        # resumes first: a suspended request already holds KV (and its
        # prefetched pages are in flight) — cheaper than a fresh prefill
        while self.suspended and len(self.running) < self.max_batch:
            req = self.suspended.pop(0)
            self.cache.activate(req.seq_id)
            self.running.append(req)
            self.metrics.bump("resumes")
        while self.queue and len(self.running) < self.max_batch:
            req = self.queue.pop(0)
            req.seq_id = self.cache.new_sequence()
            logits = self.lm.prefill(np.asarray(req.prompt, np.int32),
                                     req.seq_id)
            tok = self._sample(logits[None], [req])[0]
            req.out_tokens.append(int(tok))
            req.t_first = time.perf_counter()
            self.running.append(req)

    def _sample(self, logits, reqs) -> np.ndarray:
        out = np.zeros((len(reqs),), np.int64)
        logits = np.asarray(logits)
        for i, req in enumerate(reqs):
            if req.temperature <= 0:
                out[i] = int(np.argmax(logits[i]))
            else:
                z = logits[i] / req.temperature
                z = z - z.max()
                prob = np.exp(z) / np.exp(z).sum()
                out[i] = int(self._rng.choice(len(prob), p=prob))
        return out

    def _retire(self, req: Request) -> None:
        req.done = True
        req.t_done = time.perf_counter()
        self.cache.deactivate(req.seq_id)     # eager transit to host tier
        self.cache.release(req.seq_id)
        if self.request_log is not None:      # overlapped, never a stall
            self.request_log.append({"req_id": req.req_id,
                                     "prompt": req.prompt,
                                     "tokens": req.out_tokens})
        self.finished.append(req)

    def step(self) -> int:
        """One scheduler tick: admit, decode one token for every runner."""
        self._prefetch_ahead()
        self._admit()
        if not self.running:
            return 0
        reqs = self.running
        tokens = np.asarray([r.out_tokens[-1] for r in reqs], np.int64)
        positions = np.asarray([len(r.prompt) + len(r.out_tokens) - 1
                                for r in reqs], np.int64)
        logits = self.lm.decode_step(tokens, [r.seq_id for r in reqs],
                                     positions)
        nxt = self._sample(logits, reqs)
        still = []
        for req, tok in zip(reqs, nxt):
            req.out_tokens.append(int(tok))
            if (len(req.out_tokens) >= req.max_new_tokens
                    or tok == self.eos):
                self._retire(req)
            else:
                still.append(req)
        self.running = still
        return len(reqs)

    def _autotune_tick(self) -> None:
        if self.autotune_every <= 0 or self.request_log is None:
            return
        self._ticks_since_tune += 1
        if self._ticks_since_tune < self.autotune_every:
            return
        self._ticks_since_tune = 0
        vol = getattr(self.request_log, "vol", None)
        step = getattr(vol, "autotune_step", None)
        if step is not None:
            moves = step()
            if moves:
                self.metrics.bump("autotune_moves", len(moves))

    def run(self, max_ticks: int = 10_000) -> list[Request]:
        ticks = 0
        while (self.queue or self.running or self.suspended) \
                and ticks < max_ticks:
            self.step()
            self._autotune_tick()
            ticks += 1
        if self.request_log is not None:
            n_bad = self.request_log.drain()  # settle overlapped appends
            if n_bad:                         # surfaced, not swallowed
                self.metrics.bump("request_log_failures", n_bad)
        return self.finished
