from .engine import PagedLM, Request, ServeEngine
from .kvcache import PagedCacheConfig, PagedKVCache

__all__ = ["PagedLM", "Request", "ServeEngine", "PagedCacheConfig",
           "PagedKVCache"]
