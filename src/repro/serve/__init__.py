from .engine import PagedLM, Request, ServeEngine
from .kvcache import PagedCacheConfig, PagedKVCache
from .kvpager import KVPager

__all__ = ["PagedLM", "Request", "ServeEngine", "PagedCacheConfig",
           "PagedKVCache", "KVPager"]
