"""Volume-backed KV spill tier — the serving plane's BTT free-block pool.

The host tier in :mod:`repro.serve.kvcache` is a plain in-memory dict, so
session KV is bounded by DRAM.  This pager extends the tier hierarchy one
level down onto the async striped volume, re-using the storage stack the
paper's transit discipline already built:

  chained ``write_multi``   -> one spilled page is ONE atomic record (the
                               chained-tx journal commits the whole block
                               list or none of it — no torn KV pages)
  crc ledger                -> every record carries a wire crc32 over the
                               packed payload, verified on restore before
                               the page re-enters the host tier (the fused
                               transit-kernel checksums then re-verify the
                               int8 payload end to end on page-in)
  linked async reads        -> ``prefetch()`` issues a record's block
                               reads as an IO_LINK chain ahead of
                               ``activate()`` so the restore overlaps
                               decode (the aio qd curve's >= 1.5x)
  write-crc dedup           -> records are content-addressed (blake2b over
                               the payload): prefix-shared pages spill
                               once and share a refcounted slot

``volume`` is anything speaking the async surface — a ``StripedVolume``
or a ``repro.cluster.ClusterVolume`` (replicated KV spill that survives
node loss).  Records are fixed-size slots carved out of
``[base_lba, base_lba + capacity_blocks)``; the slot size is learned from
the first spill (every page of one cache packs to the same length) and
bounded by the device's ``max_atomic_write_blocks()``.
"""
from __future__ import annotations

import hashlib
import threading
import zlib

import numpy as np

from repro.core.metrics import Metrics

_HDR = 8                      # 4B payload length + 4B crc32, little-endian


class _Record:
    __slots__ = ("slot", "lba", "n_blocks", "key", "refs",
                 "spill_tickets", "pf_tickets")

    def __init__(self, slot: int, lba: int, n_blocks: int, key: bytes):
        self.slot = slot
        self.lba = lba
        self.n_blocks = n_blocks
        self.key = key
        self.refs = 1
        self.spill_tickets: list = []      # settled before any read
        self.pf_tickets: list | None = None   # in-flight prefetch chain


class KVPager:
    """Content-addressed, refcounted page records on an async volume."""

    def __init__(self, volume, *, base_lba: int = 0,
                 capacity_blocks: int | None = None,
                 tenant: str | None = None,
                 metrics: Metrics | None = None) -> None:
        self.vol = volume
        self.tenant = tenant
        # a pager built without explicit metrics is adopted into its
        # cache's Metrics when attached (PagedKVCache.__init__), so the
        # kv_* counters land next to the serve-plane ones
        self.own_metrics = metrics is None
        self.metrics = metrics or Metrics()
        self.block_size = volume.block_size
        self._max_rec = (volume.max_atomic_write_blocks()
                         if hasattr(volume, "max_atomic_write_blocks")
                         else None)
        self._base = base_lba
        self._cap = (volume.n_lbas - base_lba if capacity_blocks is None
                     else capacity_blocks)
        assert self._cap >= 1
        self._lock = threading.Lock()
        self._slot_blocks: int | None = None   # fixed after first spill
        self._free_slots: list[int] = []
        self._n_slots = 0
        self._records: dict[int, _Record] = {}   # handle -> record
        self._by_key: dict[bytes, int] = {}      # content hash -> handle
        self._next_handle = 0                    # handles never reused

    # ------------------------------------------------------------ geometry
    def _blocks_for(self, payload_len: int) -> int:
        return -(-(_HDR + payload_len) // self.block_size)

    def _init_slots(self, n_blocks: int) -> None:
        assert self._max_rec is None or n_blocks <= self._max_rec, \
            (f"KV page record of {n_blocks} blocks exceeds the device's "
             f"whole-object-atomic bound ({self._max_rec})")
        self._slot_blocks = n_blocks
        self._n_slots = self._cap // n_blocks
        assert self._n_slots >= 1, "spill region smaller than one KV page"
        self._free_slots = list(range(self._n_slots))

    def _slot_lba(self, slot: int) -> int:
        return self._base + slot * self._slot_blocks

    def free_slots(self) -> int:
        with self._lock:
            return (self._n_slots if self._slot_blocks is None
                    else len(self._free_slots))

    # --------------------------------------------------------------- spill
    def spill(self, payload: bytes) -> int:
        """Write one packed page to the volume (or dedup against a live
        record with the same content); returns a refcounted handle."""
        key = hashlib.blake2b(payload, digest_size=16).digest()
        with self._lock:
            h = self._by_key.get(key)
            if h is not None:
                self._records[h].refs += 1
                self.metrics.bump("kv_dedup_hits")
                return h
            n_blocks = self._blocks_for(len(payload))
            if self._slot_blocks is None:
                self._init_slots(n_blocks)
            assert n_blocks <= self._slot_blocks, \
                "KV page packed larger than the pager's slot size"
            if not self._free_slots:
                raise MemoryError(
                    f"KV spill tier exhausted ({self._n_slots} slots of "
                    f"{self._slot_blocks} blocks); grow capacity_blocks "
                    f"or release sequences")
            slot = self._free_slots.pop()
            h = self._next_handle
            self._next_handle += 1
            rec = _Record(slot, self._slot_lba(slot), n_blocks, key)
            self._records[h] = rec
            self._by_key[key] = h
            # whole-record atomicity: one chained write_multi per page
            # (block=True: a spill burst deeper than the engine window
            # waits its turn — a page is never silently dropped)
            wire = (len(payload).to_bytes(4, "little")
                    + zlib.crc32(payload).to_bytes(4, "little") + payload)
            bs = self.block_size
            blocks = [np.frombuffer(
                wire[i:i + bs].ljust(bs, b"\x00"), np.uint8)
                for i in range(0, len(wire), bs)]
            if len(blocks) > 1:
                t = self.vol.submit("write_multi", rec.lba, blocks=blocks,
                                    tenant=self.tenant, block=True)
            else:
                t = self.vol.submit("write", rec.lba, data=blocks[0],
                                    tenant=self.tenant, block=True)
            rec.spill_tickets.append(t)
            self.metrics.bump("kv_spills")
            self.metrics.bump("kv_spill_blocks", rec.n_blocks)
            return h

    # ------------------------------------------------------------ prefetch
    def prefetch(self, handles) -> int:
        """Decode-ahead restore: issue each record's block reads as a
        linked async chain (IO_LINK) so the data is in flight before
        ``activate()`` needs it.  Best-effort — a full submission window
        skips the handle (the sync path still works).  Returns how many
        chains were issued."""
        issued = 0
        for h in handles:
            with self._lock:
                rec = self._records.get(h)
                if rec is None or rec.pf_tickets is not None:
                    continue
                for t in rec.spill_tickets:     # record must be durable
                    self.vol.wait(t)
                rec.spill_tickets = []
                tickets: list = []
                prev = None
                for i in range(rec.n_blocks):
                    t = self.vol.try_submit("read", rec.lba + i,
                                            tenant=self.tenant,
                                            link_to=prev)
                    if t is None:               # window full: back off
                        for tt in tickets:
                            self._cancel(tt)
                        tickets = []
                        break
                    tickets.append(t)
                    prev = t
                if tickets:
                    rec.pf_tickets = tickets
                    issued += 1
                    self.metrics.bump("kv_prefetch_issued")
        return issued

    # --------------------------------------------------------------- fetch
    def fetch(self, handle: int) -> bytes:
        """Read one record back (prefetched payload if the decode-ahead
        chain landed, synchronous reads otherwise), verify the wire crc,
        and return the packed payload.  The record stays live — pair
        with :meth:`release` once the page is resident again."""
        with self._lock:
            rec = self._records[handle]
            spills, rec.spill_tickets = rec.spill_tickets, []
            pf, rec.pf_tickets = rec.pf_tickets, None
        for t in spills:                        # settle the write first
            self.vol.wait(t)
            if t.error is not None:
                raise t.error
        raw = None
        if pf is not None:
            ok = True
            parts = []
            for t in pf:
                self.vol.wait(t)
                if t.error is not None:         # link cancelled / device
                    ok = False
                else:
                    parts.append(self._as_bytes(t.value))
            if ok:
                raw = b"".join(parts)
                self.metrics.bump("kv_prefetch_hits")
        if raw is None:                         # sync restore path
            parts = []
            for i in range(rec.n_blocks):
                t = self.vol.submit("read", rec.lba + i,
                                    tenant=self.tenant, block=True)
                self.vol.wait(t)
                if t.error is not None:
                    raise t.error
                parts.append(self._as_bytes(t.value))
            raw = b"".join(parts)
        n = int.from_bytes(raw[:4], "little")
        crc = int.from_bytes(raw[4:8], "little")
        payload = raw[_HDR:_HDR + n]
        if len(payload) != n or zlib.crc32(payload) != crc:
            self.metrics.bump("kv_restore_crc_errors")
            raise IOError(
                f"KV spill record {handle} failed its wire checksum on "
                f"restore (lba {rec.lba}, {rec.n_blocks} blocks)")
        self.metrics.bump("kv_restores")
        return payload

    def _cancel(self, t) -> None:
        """Best-effort cancel + settle (the facade only exposes cancel
        through the engine; an already-running op just completes)."""
        eng = getattr(self.vol, "aio_engine", None)
        if eng is not None:
            eng().cancel(t)
        self.vol.wait(t)

    @staticmethod
    def _as_bytes(val) -> bytes:
        if isinstance(val, np.ndarray):
            return val.view(np.uint8).tobytes()
        return bytes(val)

    # -------------------------------------------------------------- release
    def release(self, handle: int) -> None:
        """Drop one reference; the last release frees the slot (and
        drops any unconsumed prefetch as wasted)."""
        with self._lock:
            rec = self._records[handle]
            rec.refs -= 1
            if rec.refs > 0:
                return
            del self._records[handle]
            del self._by_key[rec.key]
            pf, rec.pf_tickets = rec.pf_tickets, None
        if pf is not None:
            for t in pf:
                self._cancel(t)
            self.metrics.bump("kv_prefetch_wasted")
        for t in rec.spill_tickets:
            self.vol.wait(t)
        with self._lock:
            self._free_slots.append(rec.slot)
        self.metrics.bump("kv_spill_frees")

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        with self._lock:
            return {"records": len(self._records),
                    "slot_blocks": self._slot_blocks or 0,
                    "n_slots": self._n_slots,
                    "free_slots": (self._n_slots
                                   if self._slot_blocks is None
                                   else len(self._free_slots))}
