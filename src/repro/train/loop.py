"""Fault-tolerant training loop.

Production behaviours implemented (and exercised by tests/benchmarks):

  * **async Caiti-backed checkpointing** — ``CheckpointEngine.save_async``
    snapshots state and transits it to the block store while the next steps
    run; the commit is crash-atomic (BTT root flip).
  * **crash/restart** — ``Trainer.restore_or_init`` resumes params, opt
    state and the *data schedule* (step number is sufficient: the pipeline
    is deterministic in the step).
  * **step watchdog / straggler log** — every step's wall time feeds an EMA;
    steps slower than ``straggler_factor``× the EMA are logged with their
    step index (on a real fleet this feeds the pod-level straggler
    mitigation: re-slice or evict the slow host).
  * **elastic restore** — checkpoints store full arrays; restoring onto a
    different mesh (or device count) re-shards via the target shardings
    (see ckpt/engine.py), validated in tests with 1-device "meshes".
  * **preemption hook** — ``request_stop()`` finishes the in-flight step,
    saves, and exits cleanly (SIGTERM handling on a fleet).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.ckpt import CheckpointEngine
from repro.data import Prefetcher
from repro.models.api import Model
from repro.optim import AdamW
from .step import make_train_step


@dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    log_every: int = 10
    accum: int = 1
    straggler_factor: float = 3.0
    async_ckpt: bool = True


@dataclass
class StepStats:
    step: int
    loss: float
    dt_s: float
    straggler: bool = False


class Trainer:
    def __init__(self, model: Model, opt: AdamW, source,
                 ckpt: CheckpointEngine | None = None,
                 cfg: TrainConfig = TrainConfig(), ctx=None) -> None:
        self.model = model
        self.opt = opt
        self.source = source
        self.ckpt = ckpt
        self.cfg = cfg
        self.ctx = ctx
        self.step_fn = jax.jit(make_train_step(model, opt, ctx,
                                               accum=cfg.accum),
                               donate_argnums=(0, 1))
        self.history: list[StepStats] = []
        self.straggler_log: list[StepStats] = []
        self._stop = False
        self._ema_dt: float | None = None

    # ------------------------------------------------------------ lifecycle
    def restore_or_init(self, rng) -> tuple:
        """Returns (params, opt_state, start_step)."""
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            params_like = self.model.param_shape()
            opt_like = jax.eval_shape(self.opt.init, params_like)
            state, step = self.ckpt.restore(
                like={"params": params_like, "opt": opt_like})
            return state["params"], state["opt"], step + 1
        params = self.model.init(rng)
        return params, self.opt.init(params), 0

    def request_stop(self) -> None:
        self._stop = True

    # ----------------------------------------------------------------- run
    def run(self, rng=None, max_steps: int | None = None) -> dict:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        params, opt_state, start = self.restore_or_init(rng)
        total = min(self.cfg.total_steps,
                    start + (max_steps or self.cfg.total_steps))
        prefetch = Prefetcher(self.source, start_step=start)
        last_saved = start - 1
        try:
            for _ in range(start, total):
                step, batch = prefetch.next()
                t0 = time.perf_counter()
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                st = StepStats(step, loss, dt)
                # watchdog: EMA after warmup (jit compile pollutes step 0)
                if self._ema_dt is None:
                    self._ema_dt = dt
                elif step > start + 1:
                    if dt > self.cfg.straggler_factor * self._ema_dt:
                        st.straggler = True
                        self.straggler_log.append(st)
                    self._ema_dt = 0.9 * self._ema_dt + 0.1 * dt
                self.history.append(st)
                if self.ckpt is not None and \
                        (step + 1) % self.cfg.ckpt_every == 0:
                    state = {"params": params, "opt": opt_state}
                    if self.cfg.async_ckpt:
                        self.ckpt.save_async(step, state)
                    else:
                        self.ckpt.save(step, state)
                    last_saved = step
                if self._stop:
                    break
            # final save (sync) so restarts land at the exact stop point
            if self.ckpt is not None and self.history and \
                    self.history[-1].step != last_saved:
                self.ckpt.wait()
                self.ckpt.save(self.history[-1].step,
                               {"params": params, "opt": opt_state})
        finally:
            prefetch.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        return {"params": params, "opt_state": opt_state,
                "last_step": self.history[-1].step if self.history else -1,
                "losses": [s.loss for s in self.history],
                "stragglers": len(self.straggler_log)}
