"""Train-step factory: value_and_grad over the model loss, optional
microbatch gradient accumulation (scanned), optional int8-compressed
data-parallel gradient reduction, AdamW update.

The returned function has signature
    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
and is pure — pjit-able with the spec trees from parallel/sharding.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.models.common import MeshCtx
from repro.optim import AdamW, apply_updates


def make_train_step(model: Model, opt: AdamW, ctx: MeshCtx | None = None,
                    accum: int = 1, grad_compression: str = "none"):
    def loss_fn(params, batch):
        return model.loss(params, batch, ctx)

    def grads_of(params, batch):
        if accum <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        # microbatch accumulation: split the batch leading dim into
        # `accum` chunks and scan, summing grads (bounded activation memory)
        def split(x):
            b = x.shape[0]
            return x.reshape(accum, b // accum, *x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return jax.tree.map(jnp.add, acc,
                                (l / accum,
                                 jax.tree.map(lambda x: x / accum, g))), None

        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params))
        (loss, grads), _ = jax.lax.scan(body, zero, micro)
        return loss, grads

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if grad_compression == "int8" and ctx is not None \
                and ctx.mesh is not None and ctx.batch_axes:
            from repro.parallel.collectives import compressed_allreduce_tree
            grads = compressed_allreduce_tree(grads, ctx)
        updates, opt_state, om = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, **om}

    return train_step
