"""Discrete-event simulator for the paper's performance claims.

Why this exists: the container has ONE CPU core and a GIL — the paper's
mechanism (foreground cores fill DRAM slots while background cores drain
them to PMem) is physically unmeasurable as wall time here.  The policy
*state machines* mirror the threaded implementations in ``cache.py`` /
``policies.py`` (those remain the functional/crash-recovery ground truth);
this module re-executes them in **virtual time**, reproducing the paper's
Figure 2/3/5/6 contrasts deterministically.

Execution model (matches the paper's platform semantics):

  * a fio *job* is one submitting core; bios on a PMem block device execute
    INLINE in the submitter context, so a job's requests serialize on its
    core — ``iodepth`` controls closed-loop queueing (response time =
    queue wait + service), not extra parallelism;
  * io_submit batches amortize the syscall/stack cost across the depth
    (the paper's 'others' ≈54%% applies to depth-1 pwrite, §5.2);
  * PMem media is a shared resource: ``n_banks`` interleaved DIMMs, each a
    serial server — aggregate write bandwidth is the global bottleneck the
    background pool and foreground bypasses contend for;
  * Caiti's eviction pool = ``n_workers`` background cores, each a serial
    server that takes queued slots and writes them to PMem banks.

Cost model defaults (µs per 4 KB unless noted), calibrated so the
BTT : DAX : raw-PMem execution-time ratios match the paper's §3 study
(1.374 : 1.166 : 1) and the absolute BTT service sits in the few-µs regime
the paper's Fig. 2c shows:

  pmem_write_4k  1.95   (~2.1 GB/s/DIMM streaming store, FAST'20 [82])
  pmem_read_4k   0.75
  flog+map       0.45   (256 B entry + 8 B commit, media floor)
  btt_lane       0.35   (lane bookkeeping/locking of the kernel driver)
  dram_copy_4k   0.45   (~9 GB/s per-core memcpy)
  meta           0.15   (hash/slot-state work per cached write)
  bio_stack      2.20   (syscall+block-layer per submission, amortized by
                         min(iodepth, 16) under libaio batching)

All simulator tables print the cost model next to the results.
"""
from __future__ import annotations

import heapq
import itertools
from collections import defaultdict, deque
from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class CostModel:
    pmem_write_4k: float = 1.95
    pmem_read_4k: float = 0.75
    flog_map: float = 0.45
    btt_lane: float = 0.35
    dram_copy_4k: float = 0.45
    meta: float = 0.15
    bio_stack: float = 2.20
    dax_extra: float = 0.39       # DAX file-system write path vs raw ext4
    n_banks: int = 6              # interleaved DIMMs (768GB = 6x128GB)

    def btt_write(self) -> float:
        return self.btt_lane + self.pmem_write_4k + self.flog_map

    def btt_read(self) -> float:
        return 0.2 + self.pmem_read_4k


class Bank:
    """One serial PMem DIMM server."""

    __slots__ = ("free_at",)

    def __init__(self) -> None:
        self.free_at = 0.0

    def serve(self, t: float, dur: float) -> float:
        start = max(t, self.free_at)
        self.free_at = start + dur
        return self.free_at


class Media:
    """The interleaved PMem DIMM set — the shared bandwidth bottleneck.

    ``scale`` is the fail-slow injection knob: a limping device serves
    every request ``scale`` times slower (media-level limplock — the
    DIMM set still completes everything, it just takes 10-100x longer),
    which is exactly the failure mode hedged reads exist for.
    """

    def __init__(self, cost: CostModel) -> None:
        self.banks = [Bank() for _ in range(cost.n_banks)]
        self._rr = 0
        self.scale = 1.0               # fail-slow multiplier (1.0 = healthy)

    def write(self, t: float, dur: float) -> float:
        """Serve one block write; returns completion time."""
        self._rr = (self._rr + 1) % len(self.banks)
        return self.banks[self._rr].serve(t, dur * self.scale)

    def earliest_free(self) -> float:
        return min(b.free_at for b in self.banks)


@dataclass
class SimMetrics:
    response_us: list = field(default_factory=list)
    t_arrive: list = field(default_factory=list)
    breakdown: dict = field(default_factory=lambda: defaultdict(float))
    counts: dict = field(default_factory=lambda: defaultdict(int))

    def lat(self, arrive: float, done: float) -> None:
        self.response_us.append(done - arrive)
        self.t_arrive.append(arrive)

    def mean(self) -> float:
        return float(np.mean(self.response_us)) if self.response_us else 0.0

    def pct(self, p: float) -> float:
        if not self.response_us:
            return 0.0
        return float(np.percentile(self.response_us, p))

    def makespan_s(self) -> float:
        if not self.response_us:
            return 0.0
        a = np.asarray(self.t_arrive)
        r = np.asarray(self.response_us)
        return float((a + r).max() / 1e6)


# ----------------------------------------------------------------- policies
class PolicyBase:
    """write(t, lba) -> completion time; the submitting core is occupied
    for the whole span (inline bio execution).  Policies charge media via
    the shared ``Media`` and may consult background worker fences."""

    def __init__(self, cost: CostModel, media: Media, n_slots: int) -> None:
        self.cost = cost
        self.media = media
        self.n_slots = n_slots
        self.resident: dict[int, float] = {}
        self.dirty: set[int] = set()
        self.m = SimMetrics()
        self.drain_until = 0.0        # foreground fence during async flush

    # helpers ---------------------------------------------------------------
    def _pmem_write(self, t: float, kind: str) -> float:
        end = self.media.write(t, self.cost.btt_write())
        self.m.breakdown[kind] += end - t
        return end

    def _dram_write(self, t: float, lba: int) -> float:
        end = t + self.cost.meta + self.cost.dram_copy_4k
        self.m.breakdown["cache_metadata"] += self.cost.meta
        self.m.breakdown["cache_write_only"] += self.cost.dram_copy_4k
        self.resident[lba] = end
        self.dirty.add(lba)
        return end

    def full(self) -> bool:
        return len(self.resident) >= self.n_slots

    def _drain_all(self, t: float) -> float:
        """Write back every dirty block through the media banks."""
        end = t
        for _ in range(len(self.dirty)):
            end = self.media.write(end, self.cost.btt_write())
        self.m.counts["flush_blocks"] += len(self.dirty)
        self.dirty.clear()
        self.resident.clear()
        return end

    # bio interface ----------------------------------------------------------
    def write(self, t: float, lba: int) -> float:     # pragma: no cover
        raise NotImplementedError

    def read(self, t: float, lba: int) -> float:
        if lba in self.resident:
            self.m.counts["read_hits"] += 1
            return t + self.cost.meta + self.cost.dram_copy_4k
        self.m.counts["read_misses"] += 1
        return t + self.cost.btt_read()

    def flush(self, t: float, sync: bool) -> float:
        """PREFLUSH.  sync=False is the ext4 tick (drain proceeds on the
        side but foreground writes fence on it); sync=True is fsync."""
        t0 = t
        end = self._drain_all(t)
        self.drain_until = max(self.drain_until, end)
        self.m.breakdown["cache_flush"] += end - t0
        return end if sync else t


class SimBTTOnly(PolicyBase):
    def __init__(self, cost, media):
        super().__init__(cost, media, 0)

    def write(self, t: float, lba: int) -> float:
        return self._pmem_write(t, "pmem_write")

    def read(self, t: float, lba: int) -> float:
        return t + self.cost.btt_read()

    def flush(self, t: float, sync: bool) -> float:
        return t


class SimRawDev(PolicyBase):
    def __init__(self, cost, media, dax: bool):
        super().__init__(cost, media, 0)
        self.extra = cost.dax_extra if dax else 0.0

    def write(self, t: float, lba: int) -> float:
        end = self.media.write(t, self.cost.pmem_write_4k + self.extra)
        self.m.breakdown["pmem_write"] += end - t
        return end

    def read(self, t: float, lba: int) -> float:
        return t + self.cost.pmem_read_4k + self.extra

    def flush(self, t: float, sync: bool) -> float:
        return t


class SimPMBD(PolicyBase):
    """Watermark staging; PMBD drains a full sub-buffer on the critical
    path, PMBD-70 lets a syncer daemon drain at 70% (stall only at 100%)."""

    def __init__(self, cost, media, n_slots, n_sub: int = 8,
                 watermark: float = 1.0, daemon: bool = False) -> None:
        super().__init__(cost, media, n_slots)
        self.n_sub = n_sub
        self.watermark = watermark
        self.daemon = daemon
        self.sub_res = [dict() for _ in range(n_sub)]
        self.sub_drain_at = [0.0] * n_sub
        self.syncer = Bank()          # ONE daemon thread, as in PMBD

    def _sub_drain(self, t: float, sub: int) -> float:
        end = t
        for lba in self.sub_res[sub]:
            end = self.media.write(end, self.cost.btt_write())
            self.dirty.discard(lba)
            self.resident.pop(lba, None)
        self.sub_res[sub].clear()
        return end

    def write(self, t: float, lba: int) -> float:
        t = max(t, self.drain_until)
        sub = lba % self.n_sub
        cap = max(1, self.n_slots // self.n_sub)
        res = self.sub_res[sub]
        if lba in res:
            return self._dram_write(t, lba)
        if self.daemon:
            if len(res) >= self.watermark * cap and t >= self.sub_drain_at[sub]:
                # the single syncer daemon drains sub-buffers one at a time
                start = max(t, self.syncer.free_at)
                end = self._sub_drain(start, sub)
                self.syncer.free_at = end
                self.sub_drain_at[sub] = end
                self.m.counts["daemon_drains"] += 1
            if len(res) >= cap:
                start = max(t, self.sub_drain_at[sub])
                self.m.breakdown["cache_eviction_and_write"] += start - t
                self.m.counts["stalls"] += 1
                t = start
        elif len(res) >= cap:
            end = self._sub_drain(t, sub)
            self.m.breakdown["cache_eviction_and_write"] += end - t
            self.m.counts["stalls"] += 1
            t = end
        end = self._dram_write(t, lba)
        res[lba] = end
        return end


class SimLRU(PolicyBase):
    """2-step write on full: evict the LRU block, then DRAM write."""

    def __init__(self, cost, media, n_slots) -> None:
        super().__init__(cost, media, n_slots)
        self.order: dict[int, None] = {}

    def write(self, t: float, lba: int) -> float:
        t = max(t, self.drain_until)
        if lba in self.resident:
            self.order.pop(lba, None)
            self.order[lba] = None
            return self._dram_write(t, lba)
        if self.full():
            victim = next(iter(self.order))
            del self.order[victim]
            self.resident.pop(victim, None)
            self.dirty.discard(victim)
            end = self._pmem_write(t, "cache_eviction_and_write")
            self.m.counts["stalls"] += 1
            t = end
        self.order[lba] = None
        return self._dram_write(t, lba)


class SimCoActive(PolicyBase):
    """Cold/hot separation + proactive idle eviction (Sun et al. [61])."""

    def __init__(self, cost, media, n_slots, idle_gap: float = 5.0) -> None:
        super().__init__(cost, media, n_slots)
        self.heat: dict[int, int] = defaultdict(int)
        self.clean: dict[int, float] = {}
        self.idle_gap = idle_gap
        self.last_io = 0.0
        self.sep_cost = 0.25

    def write(self, t: float, lba: int) -> float:
        t = max(t, self.drain_until)
        if t - self.last_io > self.idle_gap and self.dirty:
            # proactive eviction filled the idle window (background)
            end = self.last_io + self.idle_gap
            for x in sorted(self.dirty, key=lambda v: self.heat[v]):
                nxt = self.media.write(end, self.cost.btt_write())
                if nxt > t:
                    break
                end = nxt
                self.dirty.discard(x)
                self.clean[x] = end
                self.m.counts["proactive"] += 1
        self.last_io = t
        self.heat[lba] += 1
        t += self.sep_cost
        self.m.breakdown["cache_metadata"] += self.sep_cost
        if lba in self.resident:
            self.clean.pop(lba, None)
            return self._dram_write(t, lba)
        if self.full():
            if self.clean:
                victim = min(self.clean, key=self.clean.get)
                self.clean.pop(victim, None)
                self.resident.pop(victim, None)
            else:
                victim = min(self.dirty, key=lambda v: self.heat[v])
                self.dirty.discard(victim)
                self.resident.pop(victim, None)
                end = self._pmem_write(t, "cache_eviction_and_write")
                self.m.counts["stalls"] += 1
                t = end
        return self._dram_write(t, lba)

    def flush(self, t: float, sync: bool) -> float:
        """Unlike a plain drain, Co-Active keeps flushed blocks cached on
        the *clean* list (its drop-clean fast path) — plus expensive list
        surgery (the paper measures 1.9x PMBD/LRU flush time)."""
        t0 = t
        t = t + 0.02 * len(self.dirty)          # list surgery
        end = t
        for lba in list(self.dirty):
            end = self.media.write(end, self.cost.btt_write())
            self.clean[lba] = end
        self.m.counts["flush_blocks"] += len(self.dirty)
        self.dirty.clear()
        self.drain_until = max(self.drain_until, end)
        self.m.breakdown["cache_flush"] += end - t0
        return end if sync else t0


class SimCaiti(PolicyBase):
    """Eager eviction through a background worker pool + conditional
    bypass.  Slot lifecycle: occupied at DRAM write, freed when the worker's
    BTT write completes (Free→Pending→Valid→Evicting→Free)."""

    def __init__(self, cost, media, n_slots, n_workers: int = 8,
                 eager: bool = True, bypass: bool = True,
                 workers: list | None = None, global_full=None,
                 evict_notify=None) -> None:
        super().__init__(cost, media, n_slots)
        self.eager = eager
        self.bypass = bypass
        # ``workers`` shares one eviction-core pool across volume shards;
        # a shared pool is drained congestion-aware (earliest-free core)
        # instead of round-robin.
        self.shared_pool = workers is not None
        self.workers = workers if workers is not None \
            else [Bank() for _ in range(n_workers)]
        self.global_full = global_full     # volume aggregate watermark hook
        self.evict_notify = evict_notify   # read-tier writeback population
        self._rr = 0
        self.freed: deque[tuple[float, int]] = deque()   # (free_t, lba)
        self.occupied = 0
        self.evict_fence = 0.0

    def _evict_bg(self, t_valid: float, lba: int) -> float:
        """Background write-back; returns slot-free time."""
        if self.shared_pool:
            w = min(self.workers, key=lambda b: b.free_at)
        else:
            self._rr = (self._rr + 1) % len(self.workers)
            w = self.workers[self._rr]
        start = max(t_valid, w.free_at)
        done = self.media.write(start + self.cost.meta,
                                self.cost.btt_write())
        w.free_at = done
        self.evict_fence = max(self.evict_fence, done)
        self.m.counts["bg_evictions"] += 1
        if self.evict_notify is not None:
            self.evict_notify(lba)         # block stays warm in the tier
        return done

    def _reclaim(self, t: float) -> None:
        while self.freed and self.freed[0][0] <= t:
            _, lba = self.freed.popleft()
            if self.resident.pop(lba, None) is not None:
                self.occupied -= 1

    def write(self, t: float, lba: int) -> float:
        self._reclaim(t)
        if lba in self.resident:
            end = self._dram_write(t, lba)
            if self.eager:
                self.dirty.discard(lba)
                self.freed.append((self._evict_bg(end, lba), lba))
            self.m.breakdown["wbq_enqueue"] += 0.05
            return end + 0.05
        locally_full = self.occupied >= self.n_slots
        if locally_full or (self.bypass and self.global_full is not None
                            and self.global_full()):
            if self.bypass:
                end = self.media.write(t + self.cost.meta,
                                       self.cost.btt_write())
                self.m.breakdown["conditional_bypass"] += end - t
                self.m.counts["bypass"] += 1
                return end
            # w/o BP: wait for the oldest in-flight eviction
            if self.freed:
                free_t, victim = self.freed.popleft()
                if self.resident.pop(victim, None) is not None:
                    self.occupied -= 1
                self.m.breakdown["cache_eviction_and_write"] += \
                    max(0.0, free_t - t)
                self.m.counts["stalls"] += 1
                t = max(t, free_t)
            else:
                end = self._pmem_write(t, "cache_eviction_and_write")
                self.m.counts["stalls"] += 1
                return end
        self.occupied += 1
        end = self._dram_write(t, lba)
        self.m.breakdown["wbq_enqueue"] += 0.05
        if self.eager:
            self.dirty.discard(lba)
            self.freed.append((self._evict_bg(end, lba), lba))
        return end + 0.05

    def flush(self, t: float, sync: bool) -> float:
        """Eager eviction leaves (almost) nothing to drain: wait on the
        in-flight fence; drain lazy leftovers ('w/o EE' ablation)."""
        t0 = t
        end = max(t, self.evict_fence)
        if self.dirty:
            for _ in range(len(self.dirty)):
                end = self.media.write(end, self.cost.btt_write())
            self.m.counts["flush_blocks"] += len(self.dirty)
            self.dirty.clear()
            if not self.eager:
                self._reclaim(end)
                self.resident.clear()
                self.occupied = 0
        self._reclaim(end)
        self.m.breakdown["cache_flush"] += end - t0
        return end if sync else t


# --------------------------------------------------------------- factories
def make_sim_policy(policy: str, cost: CostModel, media: Media,
                    cache_slots: int, caiti_workers: int = 8):
    if policy == "btt":
        return SimBTTOnly(cost, media)
    if policy in ("raw", "dax"):
        return SimRawDev(cost, media, policy == "dax")
    if policy == "pmbd":
        return SimPMBD(cost, media, cache_slots)
    if policy == "pmbd70":
        return SimPMBD(cost, media, cache_slots, watermark=0.7, daemon=True)
    if policy == "lru":
        return SimLRU(cost, media, cache_slots)
    if policy == "coactive":
        return SimCoActive(cost, media, cache_slots)
    if policy == "caiti":
        return SimCaiti(cost, media, cache_slots, n_workers=caiti_workers)
    if policy == "caiti-noee":
        return SimCaiti(cost, media, cache_slots, n_workers=caiti_workers,
                        eager=False)
    if policy == "caiti-nobp":
        return SimCaiti(cost, media, cache_slots, n_workers=caiti_workers,
                        bypass=False)
    raise ValueError(policy)


def run_sim_workload(policy: str, *, n_ops: int, n_lbas: int,
                     cache_slots: int, iodepth: int = 32, jobs: int = 1,
                     fsync_every: int = 0, read_frac: float = 0.0,
                     flush_period_us: float = 5e4, seed: int = 0,
                     caiti_workers: int = 8, value_blocks: int = 1,
                     cost: CostModel | None = None,
                     lba_stream=None) -> SimMetrics:
    """Closed-loop fio-style workload in virtual time.

    Each *job* is a serial submitting core with ``iodepth`` outstanding
    requests (arrival of request i = completion of request i-iodepth).
    ``value_blocks`` writes that many consecutive blocks per request
    (LevelDB-style bulky I/O).  ``lba_stream`` overrides the uniform
    address pattern with a custom iterator (YCSB distributions).

    ``flush_period_us`` is the ext4 journal tick.  The paper's 5 s applies
    to its 64 GB / 30 min runs; benchmark volumes here are ~300x smaller,
    so the default tick is scaled to 50 ms to preserve the
    flushes-per-byte-written ratio (stated next to every table).
    """
    cost = cost or CostModel()
    media = Media(cost)
    dev = make_sim_policy(policy, cost, media, cache_slots, caiti_workers)
    rng = np.random.default_rng(seed)
    if lba_stream is None:
        lbas = rng.integers(0, max(1, n_lbas - value_blocks), size=n_ops)
    else:
        lbas = np.fromiter(itertools.islice(lba_stream, n_ops),
                           dtype=np.int64, count=n_ops)
    is_read = (rng.random(n_ops) < read_frac) if read_frac else None
    stack = cost.bio_stack / max(1, min(iodepth, 16))

    # per-job serial cores, each with a closed-loop depth window
    per_job = n_ops // jobs
    next_tick = flush_period_us
    t_global_done = 0.0
    job_core_free = [0.0] * jobs
    completions: list[list] = [[] for _ in range(jobs)]
    idx = 0
    # round-robin interleave jobs by processing in arrival order
    heads = [j * per_job for j in range(jobs)]
    ends = [(j + 1) * per_job for j in range(jobs)]
    # simple global-time loop: at each step pick the job whose next request
    # can start earliest (deterministic, work-conserving)
    while True:
        best_j, best_start = -1, float("inf")
        for j in range(jobs):
            if heads[j] >= ends[j]:
                continue
            k = heads[j] - j * per_job
            arrive = completions[j][k - iodepth] if k >= iodepth else 0.0
            start = max(arrive, job_core_free[j])
            if start < best_start:
                best_start, best_j = start, j
        if best_j < 0:
            break
        j = best_j
        i = heads[j]
        heads[j] += 1
        k = i - j * per_job
        arrive = completions[j][k - iodepth] if k >= iodepth else 0.0
        t = max(arrive, job_core_free[j])
        # ext4 journal tick (async PREFLUSH)
        while t >= next_tick:
            dev.flush(next_tick, sync=False)
            next_tick += flush_period_us
        t_proc = t + stack
        dev.m.breakdown["others"] += stack
        lba = int(lbas[i])
        if is_read is not None and is_read[i]:
            done = dev.read(t_proc, lba)
        else:
            done = dev.write(t_proc, lba)
            for extra in range(1, value_blocks):
                done = dev.write(done, lba + extra)
        if fsync_every and (k + 1) % fsync_every == 0:
            done = dev.flush(done, sync=True)
        job_core_free[j] = done
        completions[j].append(done)
        dev.m.lat(arrive, done)
        t_global_done = max(t_global_done, done)
    # terminal drain: every buffered block must reach the media before the
    # run "ends" (fio exit fsync) — keeps makespans bandwidth-conserving
    t_global_done = max(t_global_done,
                        dev.flush(t_global_done, sync=True))
    dev.m.counts["makespan_us"] = int(t_global_done)
    return dev.m


# --------------------------------------------------- chained-tx modeling
def chain_commit_steps(n_blocks: int, span: int) -> list[tuple]:
    """The ordered persistence steps of one chained-tx logical write, as
    ``repro.volume.VolumeJournal.log_chain`` + the in-place phase issue
    them.  Each step is one atomic BTT block write:

      ("payload", link, i)   — journal payload block i of link ``link``
      ("header", link)       — a non-tail link header
      ("tail_header",)       — THE commit point (written last of all
                               headers; everything after it rolls forward,
                               everything before it leaves the old image)
      ("inplace", i)         — in-place data write of logical block i

    The threaded crash tests cross-validate the real volume against this
    model: for every injected crash point the surviving image must match
    :func:`chain_crash_outcome`.
    """
    assert n_blocks >= 1 and span >= 1
    links = [min(span, n_blocks - off) for off in range(0, n_blocks, span)]
    steps: list[tuple] = []
    for link, n in enumerate(links):
        steps.extend(("payload", link, i) for i in range(n))
    steps.extend(("header", link) for link in range(len(links) - 1))
    steps.append(("tail_header",))
    steps.extend(("inplace", i) for i in range(n_blocks))
    return steps


def chain_crash_outcome(n_blocks: int, span: int, crash_step: int) -> str:
    """Post-recovery image when the crash kills step ``crash_step``
    (0-based; that step and everything after it never execute):
    ``"old"`` before the tail header lands, ``"new"`` after — never a
    torn mix (the whole-object atomicity claim)."""
    steps = chain_commit_steps(n_blocks, span)
    tail_idx = steps.index(("tail_header",))
    return "new" if crash_step > tail_idx else "old"


# ---------------------------------------------------------------- volumes
class SimReadTier:
    """Virtual-time read tier: the REAL ``repro.volume.ReadTier`` in
    object mode (keys only — block data is not simulated), so the
    simulator validates the exact CLOCK/second-chance policy the
    threaded implementation runs, not a reimplementation of it."""

    def __init__(self, n_slots: int) -> None:
        from repro.volume.read_tier import ReadTier   # no import cycle at
        self._tier = ReadTier(block_size=None,        # call time
                              n_slots=max(1, n_slots))

    def hit(self, key) -> bool:
        return self._tier.lookup(key) is not None

    def insert(self, key) -> None:
        self._tier.insert(key, True)

    def invalidate(self, key) -> None:
        self._tier.invalidate(key)

    def hit_rate(self) -> float:
        return self._tier.hit_rate()

    @property
    def hits(self) -> int:
        return self._tier.hits

    @property
    def misses(self) -> int:
        return self._tier.misses


def zipf_lba_stream(rng, n_ops: int, n_lbas: int,
                    theta: float = 0.99) -> np.ndarray:
    """YCSB-style bounded zipfian addresses: rank k drawn with probability
    proportional to 1/(k+1)^theta, ranks scattered over the LBA space by a
    fixed permutation so the hot set spreads across volume shards."""
    w = 1.0 / np.power(np.arange(1, n_lbas + 1, dtype=np.float64), theta)
    ranks = rng.choice(n_lbas, size=n_ops, p=w / w.sum())
    perm = np.random.default_rng(12345).permutation(n_lbas)
    return perm[ranks]


class SimVolume:
    """Virtual-time model of the striped multi-device volume.

    Each shard is a full device (its own interleaved DIMM set = ``Media``)
    fronted by the per-policy cache; caiti shards share ONE background
    eviction-core pool, drained congestion-aware (earliest-free core), and
    honor the volume's aggregate-staged watermark for global conditional
    bypass.  ``cache_slots`` and ``n_workers`` are VOLUME totals, so a
    1-shard and an N-shard volume stage the same bytes with the same
    eviction cores — what N buys is media parallelism and shorter
    per-shard queues, which is the paper's contended resource.

    The layered read path (PR 2) is modeled in virtual time:

      * ``tier_slots > 0`` adds a volume-wide clean DRAM read tier.  A
        tier hit costs ``meta + dram_copy_4k`` (a dict probe + one DRAM
        copy); misses fill the tier, writes invalidate, caiti eviction
        writebacks re-populate — the same protocol as the threaded tier;
      * volume *read misses go through the shard's Media banks*: PMem
        reads share the DIMMs with eviction/bypass write traffic, so a
        read-heavy tenant feels the background write pressure (van Renen
        et al.'s read-write interference).  Transit-cache hits stay
        DRAM-priced;
      * ``degraded_every = N`` fails primary-shard verification on every
        Nth backend read: the read pays a second, replica-shard media
        round trip (the degraded-read detour).
    """

    def __init__(self, policy: str, cost: CostModel, *, n_shards: int,
                 cache_slots: int, n_workers: int = 8,
                 stripe_blocks: int = 64, watermark: float = 1.0,
                 tier_slots: int = 0, degraded_every: int = 0,
                 commit_window_us: float = 0.0,
                 log_window_us: float = 0.0,
                 journal_span: int = 8,
                 aio_workers: int = 0,
                 slow_shard: int | None = None,
                 slow_factor: float = 25.0) -> None:
        self.policy = policy
        self.cost = cost
        self.n_shards = n_shards
        self.stripe_blocks = stripe_blocks
        self.medias = [Media(cost) for _ in range(n_shards)]
        # fail-slow injection: one shard's whole DIMM set limps at
        # slow_factor x service time (it never fails — the throughput
        # counters look healthy, only the tail collapses)
        self.slow_shard = slow_shard
        if slow_shard is not None:
            self.medias[slow_shard].scale = slow_factor
        self.read_tier = SimReadTier(tier_slots) if tier_slots > 0 else None
        self.degraded_every = degraded_every
        self._backend_reads = 0
        self.vcounts: dict = defaultdict(int)
        # group commit: fsync checkpoints serialize on the commit lock
        # (one drain + one superblock header write per shard per commit);
        # with a window > 0 concurrent fsyncs coalesce behind a leader
        self.commit_window_us = commit_window_us
        self._commit_lock = Bank()             # the volume _txlock
        self._gc_start: float | None = None    # leader's scheduled start
        self._gc_done = 0.0
        # batched log pipeline: chained-tx log() calls serialize on the
        # same volume tx lock; with log_window_us > 0 concurrent calls
        # coalesce into one slot-shard pass behind a leader
        self.log_window_us = log_window_us
        self.journal_span = journal_span
        self._log_lock = Bank()
        self._lb_start: float | None = None    # leader's scheduled start
        self._lb_done = 0.0
        # async frontend (SimVolume.submit/poll): engine dispatch cores
        # modeled as serial servers — a submitted op runs on the
        # earliest-free core instead of occupying the submitting core
        self._aio_cores = [Bank() for _ in range(aio_workers)]
        self._aio_next = itertools.count(1)
        self._aio_open: dict[int, float] = {}   # ticket -> completion time
        slots_per = max(1, cache_slots // n_shards)
        self._total_slots = slots_per * n_shards
        self._watermark_slots = watermark * self._total_slots
        self._use_watermark = policy.startswith("caiti") and watermark < 1.0
        # control-plane surface: the hedge trigger the autotune workload
        # reads (mirrors cfg.hedge_delay_us on the threaded volume)
        self.hedge_delay_us = 1000.0
        if policy.startswith("caiti"):
            pool = [Bank() for _ in range(n_workers)]
            self.shards = [
                SimCaiti(cost, self.medias[i], slots_per,
                         eager=(policy != "caiti-noee"),
                         bypass=(policy != "caiti-nobp"),
                         workers=pool,
                         global_full=(self._over_watermark
                                      if self._use_watermark else None),
                         evict_notify=(self._make_evict_notify(i)
                                       if self.read_tier is not None
                                       else None))
                for i in range(n_shards)
            ]
        else:
            self.shards = [make_sim_policy(policy, cost, self.medias[i],
                                           slots_per)
                           for i in range(n_shards)]

    def _make_evict_notify(self, shard: int):
        return lambda local: self.read_tier.insert((shard, local))

    def _over_watermark(self) -> bool:
        staged = sum(s.occupied for s in self.shards)
        return staged >= self._watermark_slots

    def _map(self, lba: int) -> tuple[int, int]:
        st, within = divmod(lba, self.stripe_blocks)
        row, shard = divmod(st, self.n_shards)
        return shard, row * self.stripe_blocks + within

    def write(self, t: float, lba: int) -> float:
        shard, local = self._map(lba)
        if self.read_tier is not None:
            self.read_tier.invalidate((shard, local))
        return self.shards[shard].write(t, local)

    def read(self, t: float, lba: int) -> float:
        return self.read_ex(t, lba)[0]

    def read_ex(self, t: float, lba: int) -> tuple[float, str]:
        """(completion time, serving tier) — 'transit' | 'tier' |
        'backend'; the workload loop prices tier-aware WFQ charges with
        the source, like the threaded ``CaitiCache.read_ex``."""
        shard, local = self._map(lba)
        s = self.shards[shard]
        if local in s.resident:                  # staged write: DRAM hit
            return s.read(t, local), "transit"
        key = (shard, local)
        if self.read_tier is not None and self.read_tier.hit(key):
            self.vcounts["tier_hits"] += 1
            return t + self.cost.meta + self.cost.dram_copy_4k, "tier"
        # backend read: contends for the shard's DIMM banks with the
        # eviction/bypass write traffic
        self.vcounts["read_misses"] += 1
        end = self.medias[shard].write(t, self.cost.btt_read())
        if self.read_tier is not None:
            self.read_tier.insert(key)
        self._backend_reads += 1
        if self.degraded_every and \
                self._backend_reads % self.degraded_every == 0:
            # primary verification failed: replica round trip on its shard
            self.vcounts["degraded_reads"] += 1
            replica_shard = (shard + 1) % self.n_shards
            end = self.medias[replica_shard].write(
                end + self.cost.meta, self.cost.btt_read())
        return end, "backend"

    # ------------------------------------------------------ hedged reads
    def read_replica(self, t: float, lba: int, replica: int = 1) -> float:
        """Backend read of ``lba``'s replica copy on the rotated shard —
        the hedge leg.  No tier interaction: the hedge goes straight to
        the replica's media banks (the threaded engine submits the hedge
        ticket without ``out=`` for the same reason)."""
        shard, _local = self._map(lba)
        rshard = (shard + replica) % self.n_shards
        self.vcounts["replica_reads"] += 1
        return self.medias[rshard].write(t, self.cost.btt_read())

    def hedged_read(self, t: float, lba: int,
                    delay_us: float) -> tuple[float, str]:
        """Virtual-time hedged read, mirroring the threaded
        ``StripedVolume.hedged_read`` counter semantics exactly: the
        primary leg is issued at ``t``; if it has not completed within
        ``delay_us`` the replica leg fires at ``t + delay_us`` and the
        FIRST completion is served.  A hedge retires as ``hedges_won``
        iff its result is served, else ``hedges_cancelled`` — so
        ``hedges_fired == hedges_won + hedges_cancelled`` holds here the
        same way ``Metrics.tail_path()`` asserts it.  The loser's media
        time is NOT clawed back: cancellation frees the caller, not bank
        time already scheduled (matching the engine, where a discarded
        in-flight read still drains on its worker)."""
        end_p, _src = self.read_ex(t, lba)
        if end_p - t <= delay_us:
            return end_p, "primary"          # fast path: no hedge fired
        self.vcounts["hedges_fired"] += 1
        end_h = self.read_replica(t + delay_us, lba)
        if end_h < end_p:
            self.vcounts["hedges_won"] += 1
            return end_h, "hedge"
        self.vcounts["hedges_cancelled"] += 1
        return end_p, "primary"

    # ------------------------------------------------------ batched log
    def _issue_log_writes(self, start: float, n_writes: int) -> float:
        """Issue one chain's slot-shard writes AT ``start`` (no cross-
        write ordering): they queue on the striped shard DIMM banks and
        overlap — the batch-mode issue pattern."""
        end = start
        for k in range(n_writes):
            end = max(end, self.medias[k % self.n_shards].write(
                start, self.cost.btt_write()))
        return end

    def log(self, t: float, n_blocks: int) -> float:
        """One chained-tx logged write of ``n_blocks`` payload blocks
        (``journal_span`` blocks per link; writes = payloads + one header
        per link, the last being the tail).

        Per-call (``log_window_us == 0``): the chain's slot-shard writes
        are strictly ordered (headers after payloads, tail last) and the
        volume tx lock serializes callers — every journal block write
        waits out the previous one, the paper's on-demand small-write
        stall.  Batched: callers coalescing into a leader's batch share
        ONE tx-lock pass; within the batch, member chains have no
        cross-ordering until the shared tail pass, so their writes fan
        out across the striped shard DIMM banks in parallel, plus one
        tail-fence write per batch.  (Like ``fsync``'s group-commit
        model, a follower simulated later but inside the window rides
        the leader's batch — slightly optimistic for followers; the
        per-call baseline has no such slack, so the contrast is an upper
        bound well clear of the 1.3x acceptance bar.)"""
        self.vcounts["log_calls"] += 1
        links = -(-n_blocks // self.journal_span)
        self.vcounts["log_links"] += links
        writes = n_blocks + links            # payloads + headers (tail incl)
        if self.log_window_us <= 0:
            self.vcounts["log_batches"] += 1
            start = max(t, self._log_lock.free_at)
            end = start
            for k in range(writes):          # strictly ordered pass
                end = self.medias[k % self.n_shards].write(
                    end, self.cost.btt_write())
            self._log_lock.free_at = end
            return end
        if self._lb_start is not None and t <= self._lb_start:
            # coalesce: ride the gathering batch
            self.vcounts["log_coalesced"] += 1
            end = self._issue_log_writes(self._lb_start, writes)
            self._lb_done = max(self._lb_done, end)
            return self._lb_done
        # lead a new batch, gathering until t + window
        self.vcounts["log_batches"] += 1
        self._lb_start = t + self.log_window_us
        start = max(self._lb_start, self._log_lock.free_at)
        end = self._issue_log_writes(start, writes)
        end = self.medias[0].write(end, self.cost.btt_write())  # tail fence
        self._log_lock.free_at = end
        self._lb_done = end
        return end

    def flush(self, t: float, sync: bool) -> float:
        return max(s.flush(t, sync) for s in self.shards)

    def _commit(self, t: float) -> float:
        """One full checkpoint: serialize on the commit lock, drain every
        shard, then one applied-mark superblock header write per shard
        (the fsync round trip group commit amortizes)."""
        start = max(t, self._commit_lock.free_at)
        end = self.flush(start, sync=True)
        for m in self.medias:
            end = max(end, m.write(end, self.cost.btt_write()))
        self._commit_lock.free_at = end
        self.vcounts["commits"] += 1
        return end

    def fsync(self, t: float) -> float:
        """fsync with optional group commit: a caller arriving while a
        commit is still gathering (scheduled to start at ``_gc_start``)
        coalesces onto it; otherwise it leads a new commit that starts
        ``commit_window_us`` later to gather followers.

        Modeling note: the leader's drain is computed eagerly at its own
        call, so a follower whose write is *simulated later* (but with
        virtual time inside the window) rides the commit without adding
        to its drain — slightly optimistic for followers; their staged
        blocks drain at the next commit instead.  The per-call baseline
        has no such slack, so windowed-vs-per-call contrasts are upper
        bounds; the acceptance margin (>= 3x at window=20us vs the 1.3x
        bar) does not hinge on it."""
        self.vcounts["fsync_calls"] += 1
        if self.commit_window_us <= 0:
            return self._commit(t)
        if self._gc_start is not None and t <= self._gc_start:
            self.vcounts["fsync_coalesced"] += 1
            return self._gc_done
        self._gc_start = t + self.commit_window_us
        self._gc_done = self._commit(self._gc_start)
        return self._gc_done

    # --------------------------------------------------- async frontend
    def submit(self, t: float, op: str, lba: int = 0,
               n_blocks: int = 1) -> int:
        """Virtual-time model of ``StripedVolume.submit``: the op is
        dispatched to the earliest-free engine core (a serial server)
        instead of occupying the submitting core, so a tenant with
        queue depth > 1 overlaps its own ops across cores, shard DIMM
        banks and the background eviction pool.  Returns a ticket id;
        :meth:`poll` / :meth:`complete_time` surface the completion.
        Requires ``aio_workers > 0`` at construction."""
        assert self._aio_cores, "SimVolume built without aio_workers"
        core = min(self._aio_cores, key=lambda b: b.free_at)
        start = max(t, core.free_at)
        if op == "write":
            done = start
            for i in range(n_blocks):
                done = self.write(done, lba + i)
        elif op == "read":
            done = self.read(start, lba)
        elif op == "log":
            done = self.log(start, n_blocks)
            for i in range(n_blocks):
                done = self.write(done, lba + i)
        elif op == "fsync":
            done = self.fsync(start)
        else:
            raise ValueError(op)
        core.free_at = done
        tid = next(self._aio_next)
        self._aio_open[tid] = done
        self.vcounts["aio_submits"] += 1
        return tid

    def complete_time(self, tid: int) -> float:
        """Completion time of a still-open ticket (the driver's closed
        -loop gate; the ticket stays open until polled)."""
        return self._aio_open[tid]

    def poll(self, t: float) -> list[int]:
        """Tickets complete at time ``t``, oldest first (the shared
        completion ring); polled tickets are retired."""
        out = sorted((d, tid) for tid, d in self._aio_open.items()
                     if d <= t)
        for _d, tid in out:
            del self._aio_open[tid]
        return [tid for _d, tid in out]

    # ----------------------------------------------------- control plane
    def set_knobs(self, changes: dict) -> None:
        """Apply control-plane knob moves in virtual time — the sim-side
        mirror of ``StripedVolume._apply_knobs`` (windows stay µs here;
        the threaded volume converts to seconds).  ``scan_threshold``
        has no sim-side analogue (the sim tier has no scan detector) and
        is ignored."""
        if "commit_window_us" in changes:
            self.commit_window_us = float(changes["commit_window_us"])
        if "log_window_us" in changes:
            self.log_window_us = float(changes["log_window_us"])
        if "bypass_watermark" in changes:
            frac = float(changes["bypass_watermark"])
            self._watermark_slots = frac * self._total_slots
            if self.policy.startswith("caiti") and frac < 1.0 \
                    and not self._use_watermark:
                # the hook was not installed at construction (watermark
                # started at 1.0): retrofit it onto every caiti shard
                self._use_watermark = True
                for s in self.shards:
                    if hasattr(s, "global_full"):
                        s.global_full = self._over_watermark
        if "hedge_delay_us" in changes:
            self.hedge_delay_us = float(changes["hedge_delay_us"])

    def staged_frac(self) -> float:
        staged = sum(getattr(s, "occupied", 0) for s in self.shards)
        return staged / max(1, self._total_slots)

    def counts(self) -> dict:
        agg: dict = defaultdict(int)
        for s in self.shards:
            for k, v in s.m.counts.items():
                agg[k] += v
        for k, v in self.vcounts.items():
            agg[k] += v
        if self.read_tier is not None:
            agg["tier_misses"] += self.read_tier.misses
        return dict(agg)


def run_volume_sim_workload(policy: str, *, n_shards: int, n_lbas: int,
                            cache_slots: int, tenants: list[dict],
                            iodepth: int = 32, qdepth: int = 32,
                            n_workers: int = 8, stripe_blocks: int = 64,
                            watermark: float = 1.0, fsync_every: int = 0,
                            read_frac: float = 0.0,
                            flush_period_us: float = 5e4, seed: int = 0,
                            tier_slots: int = 0, degraded_every: int = 0,
                            lba_dist: str = "uniform",
                            zipf_theta: float = 0.99,
                            commit_window_us: float = 0.0,
                            log_blocks: int = 0,
                            log_window_us: float = 0.0,
                            tier_hit_cost_frac: float = 0.125,
                            cost: CostModel | None = None) -> dict:
    """Closed-loop multi-tenant fio workload against a striped volume.

    ``tenants`` — dicts with keys ``n_ops`` plus optional ``name``,
    ``jobs`` (submitting cores for this tenant, default 4), ``weight``
    (WFQ share, default 1.0) and ``rate_mbps`` (token-bucket cap, 0 =
    unlimited; MB/s == bytes/µs, so bucket math is exact in virtual time).

    Execution model matches ``run_sim_workload``: every job is a serial
    submitting core (inline bio execution), with an ``iodepth``-window
    closed loop feeding arrival times.  On top, the volume applies the QoS
    disciplines of ``repro.volume.qos`` in virtual time: at most
    ``qdepth`` requests are dispatched-but-incomplete volume-wide, and
    when cores contend for a dispatch slot the smallest SFQ start tag
    ``S = max(V, F_tenant)`` wins, with ``F_tenant += bytes/weight``.
    Token buckets delay a job's arrival before tags are assigned, so a
    rate-capped tenant never accrues scheduling credit while throttled.

    Read-path knobs (PR 2): ``tier_slots`` enables the volume read tier,
    ``degraded_every`` injects a primary-verification failure on every
    Nth backend read, ``lba_dist='zipf'`` (with ``zipf_theta``) replaces
    the uniform address pattern with a YCSB-style skewed one.

    Commit-path knobs (PR 3): ``fsync_every`` routes through
    ``SimVolume.fsync`` — each commit serializes on the volume commit
    lock and pays one superblock header write per shard.
    ``commit_window_us > 0`` enables group commit: fsyncs arriving while
    a leader is gathering coalesce onto its single checkpoint, so N
    syncing tenants pay one header-write round trip instead of N
    (``counts['fsync_calls']`` vs ``counts['commits']``).

    Batched-log + fairness knobs (PR 4): ``log_blocks > 0`` turns every
    write op into a chained-tx logged write of that many blocks through
    ``SimVolume.log`` (then staged in place); ``log_window_us > 0``
    coalesces concurrent log calls into batched slot-shard passes.
    Tenant dicts accept a per-tenant ``read_frac`` (overriding the
    global one) so read-heavy and write-heavy tenants can share one
    volume, and SFQ finish tags are charged TIER-AWARE: an op's virtual
    time is its priced bytes — a DRAM-served read (transit or tier hit)
    costs ``tier_hit_cost_frac`` of its size, everything else full price
    — so the scheduler equalizes *cost* across mixed workloads
    (``per_tenant[..]['contended_charged_share']`` converges to the
    weight share).
    """
    cost = cost or CostModel()
    vol = SimVolume(policy, cost, n_shards=n_shards, cache_slots=cache_slots,
                    n_workers=n_workers, stripe_blocks=stripe_blocks,
                    watermark=watermark, tier_slots=tier_slots,
                    degraded_every=degraded_every,
                    commit_window_us=commit_window_us,
                    log_window_us=log_window_us)
    rng = np.random.default_rng(seed)
    nt = len(tenants)
    names = [t.get("name", f"t{j}") for j, t in enumerate(tenants)]
    weights = [float(t.get("weight", 1.0)) for t in tenants]
    rates = [float(t.get("rate_mbps", 0.0)) for t in tenants]   # bytes/us
    bursts = [float(t.get("burst_bytes", 64 << 10)) for t in tenants]
    rfracs = [float(t.get("read_frac", read_frac)) for t in tenants]
    bs = 4096.0
    stack = cost.bio_stack / max(1, min(iodepth, 16))

    # expand tenants into streams (one per submitting core)
    st_tenant: list[int] = []
    st_ops: list[np.ndarray] = []
    st_reads: list = []
    for j, t in enumerate(tenants):
        jobs = max(1, int(t.get("jobs", 4)))
        per = max(1, int(t["n_ops"]) // jobs)
        for _ in range(jobs):
            st_tenant.append(j)
            if lba_dist == "zipf":
                st_ops.append(zipf_lba_stream(rng, per, n_lbas, zipf_theta))
            else:
                st_ops.append(rng.integers(0, n_lbas, size=per))
            st_reads.append(rng.random(per) < rfracs[j] if rfracs[j]
                            else None)
    ns = len(st_tenant)
    heads = [0] * ns
    core_free = [0.0] * ns
    completions: list[list[float]] = [[] for _ in range(ns)]
    charged: list[list[tuple[float, float]]] = [[] for _ in range(nt)]
    metrics = [SimMetrics() for _ in range(nt)]
    finish = [0.0] * nt                  # SFQ per-tenant finish tags
    vtime = 0.0                          # virtual time = last start tag
    tb_tokens = list(bursts)
    tb_time = [0.0] * nt
    inflight: list[float] = []           # completion-time heap
    t_now = 0.0
    next_tick = flush_period_us
    t_done = 0.0

    def tb_ready(j: int, arrive: float) -> float:
        if rates[j] <= 0:
            return arrive
        avail = min(bursts[j], tb_tokens[j]
                    + (arrive - tb_time[j]) * rates[j])
        if avail >= bs:
            return arrive
        return tb_time[j] + (bs - tb_tokens[j]) / rates[j]

    def tb_take(j: int, start: float) -> None:
        if rates[j] <= 0:
            return
        tb_tokens[j] = min(bursts[j], tb_tokens[j]
                           + (start - tb_time[j]) * rates[j]) - bs
        tb_time[j] = start

    while True:
        # bounded volume window: wait for a slot before dispatching
        while len(inflight) >= qdepth:
            t_now = max(t_now, heapq.heappop(inflight))
        # candidate request per stream: (ready time, tenant SFQ tag)
        cands = []
        for s in range(ns):
            k = heads[s]
            if k >= len(st_ops[s]):
                continue
            j = st_tenant[s]
            arrive = completions[s][k - iodepth] if k >= iodepth else 0.0
            ready = max(tb_ready(j, arrive), core_free[s])
            s_tag = max(vtime, finish[j])
            cands.append((ready, s_tag, s, arrive))
        if not cands:
            break
        elig = [c for c in cands if c[0] <= t_now + 1e-9]
        if not elig:
            t_now = min(c[0] for c in cands)
            elig = [c for c in cands if c[0] <= t_now + 1e-9]
        ready, s_tag, s, arrive = min(elig, key=lambda c: (c[1], c[0], c[2]))
        j = st_tenant[s]
        heads[s] += 1
        vtime = max(vtime, s_tag)
        start = max(t_now, ready)
        tb_take(j, start)
        while start >= next_tick:          # ext4 journal tick
            vol.flush(next_tick, sync=False)
            next_tick += flush_period_us
        i = heads[s] - 1
        lba = int(st_ops[s][i])
        t_proc = start + stack
        metrics[j].breakdown["others"] += stack
        if st_reads[s] is not None and st_reads[s][i]:
            done, source = vol.read_ex(t_proc, lba)
            # tier-aware virtual time: a DRAM-served read is priced at
            # the admission layer's fraction of a PMem round trip
            op_cost = bs * (tier_hit_cost_frac if source != "backend"
                            else 1.0)
        elif log_blocks > 0:
            # chained-tx logged write: journal pass first (the commit
            # point), then the payload stages in place
            done = vol.log(t_proc, log_blocks)
            for k in range(log_blocks):
                done = vol.write(done, lba + k)
            op_cost = bs * log_blocks
        else:
            done = vol.write(t_proc, lba)
            op_cost = bs
        # SFQ: the tag was assigned pre-dispatch; the finish tag advances
        # by the op's PRICED bytes (dispatch is serialized, so the next
        # candidate scan always sees the settled tag)
        finish[j] = s_tag + op_cost / weights[j]
        charged[j].append((done, op_cost))
        if fsync_every and (i + 1) % fsync_every == 0:
            done = vol.fsync(done)
        heapq.heappush(inflight, done)
        completions[s].append(done)
        core_free[s] = done              # inline bio: core busy to completion
        metrics[j].lat(arrive, done)
        t_now = start
        t_done = max(t_done, done)

    t_done = max(t_done, vol.flush(t_done, sync=True))   # exit fsync
    counts = vol.counts()
    counts["makespan_us"] = int(t_done)
    writes = sum(len(ops) for ops in st_ops)
    per_tenant = {}
    spans = [0.0] * nt
    done_ops = [0] * nt
    for s in range(ns):
        j = st_tenant[s]
        done_ops[j] += len(completions[s])
        if completions[s]:
            spans[j] = max(spans[j], completions[s][-1])
    # fair-share window: while EVERY tenant still has work, throughput
    # must split by weight; after the fastest stream drains the remaining
    # tenants legitimately speed up, so whole-span ratios understate QoS
    t_contended = min((s for s in spans if s > 0), default=0.0)
    # charged virtual bytes inside the contended window: while every
    # tenant still has work, the SFQ discipline equalizes PRICED service
    # per weight — the fairness claim for mixed read/write tenants
    c_charged = [sum(c for d, c in charged[j] if d <= t_contended + 1e-9)
                 for j in range(nt)]
    tot_charged = sum(c_charged) or 1.0
    tot_weight = sum(weights) or 1.0
    for j in range(nt):
        c_ops = sum(1 for s in range(ns) if st_tenant[s] == j
                    for c in completions[s] if c <= t_contended + 1e-9)
        per_tenant[names[j]] = {
            "ops": done_ops[j],
            # a tenant's throughput is over ITS OWN stream's span (closed
            # loop: a favored tenant finishes its ops sooner, not "more")
            "mb_s": done_ops[j] * bs / max(spans[j], 1e-9),  # B/us == MB/s
            "span_us": spans[j],
            "contended_mb_s": c_ops * bs / max(t_contended, 1e-9),
            "charged_vbytes": sum(c for _, c in charged[j]),
            "contended_charged_share": c_charged[j] / tot_charged,
            "weight_share": weights[j] / tot_weight,
            "mean_us": metrics[j].mean(),
            "p9999_us": metrics[j].pct(99.99),
            "weight": weights[j],
            "rate_mbps": rates[j],
        }
    return {
        "policy": policy,
        "n_shards": n_shards,
        "makespan_us": t_done,
        "agg_mb_s": writes * bs / max(t_done, 1e-9),
        "bypass_rate": counts.get("bypass", 0) / max(1, writes),
        "tier_hit_rate": (vol.read_tier.hit_rate()
                          if vol.read_tier is not None else 0.0),
        "degraded_reads": counts.get("degraded_reads", 0),
        "counts": counts,
        "per_tenant": per_tenant,
    }


def run_autotune_sim_workload(policy: str = "caiti", *, phases: list[dict],
                              n_shards: int = 4, n_lbas: int = 1 << 16,
                              cache_slots: int = 4096, n_workers: int = 8,
                              iodepth: int = 8, stripe_blocks: int = 64,
                              watermark: float = 0.9, tier_slots: int = 0,
                              autotune=None,
                              control_every_us: float = 2000.0,
                              commit_window_us: float = 0.0,
                              log_window_us: float = 0.0,
                              journal_span: int = 8, seed: int = 0,
                              cost: CostModel | None = None) -> dict:
    """Phase-change trace against one :class:`SimVolume`, with an
    optional live control plane — the tuned-vs-frozen acceptance driver
    for ``benchmarks/scenarios.py``.

    ``phases`` run SEQUENTIALLY in virtual time over the same volume
    (so cache/tier state carries across the change — the whole point of
    a phase-change trace).  Each phase dict:

      ``name``      phase label (per-phase result key)
      ``tenants``   list of dicts: ``n_ops`` plus optional ``name``,
                    ``jobs`` (streams, default 2), ``read_frac``,
                    ``fsync_every`` (fsync per N stream ops, 0 = never),
                    ``log_blocks`` (writes become chained-tx logged
                    writes of that many blocks), ``think_us`` (per-op
                    idle after completion — the diurnal lull knob)
      ``lba_dist``  'uniform' | 'zipf' | 'seq' (per-stream sequential
                    runs — the ckpt-restore/backup scan shape)

    ``autotune`` is a REAL :class:`repro.volume.autotune.Controller`
    (the sim validates the actual policy object, same idiom as
    ``SimReadTier``): every ``control_every_us`` of virtual time the
    driver computes one signal window from the volume's counter deltas
    and applies whatever knob moves the controller votes through
    (:meth:`SimVolume.set_knobs`).  ``autotune=None`` is the frozen
    baseline: knobs stay at their configured values for the whole
    trace.  Returns per-phase and whole-trace throughput/latency plus
    the knob trace (every applied move with its virtual timestamp) so
    tests can assert clamp safety and benches can plot convergence.
    """
    cost = cost or CostModel()
    vol = SimVolume(policy, cost, n_shards=n_shards,
                    cache_slots=cache_slots, n_workers=n_workers,
                    stripe_blocks=stripe_blocks, watermark=watermark,
                    tier_slots=tier_slots,
                    commit_window_us=commit_window_us,
                    log_window_us=log_window_us,
                    journal_span=journal_span)
    if autotune is not None:
        autotune.bind({"commit_window_us": commit_window_us,
                       "log_window_us": log_window_us,
                       "bypass_watermark": watermark})
    rng = np.random.default_rng(seed)
    bs = 4096.0
    stack = cost.bio_stack / max(1, min(iodepth, 16))
    knob_trace: list[tuple[float, dict]] = []
    per_phase: dict[str, dict] = {}
    all_lats: list[float] = []
    t_phase = 0.0
    next_ctl = control_every_us
    prev_counts: dict = {}
    win_ops = 0
    win_reads = 0
    win_writes = 0
    win_tenant_lats: dict[str, list] = {}

    def control_tick(t: float) -> None:
        nonlocal prev_counts, win_ops, win_reads, win_writes
        cur = vol.counts()
        d = {k: cur.get(k, 0) - prev_counts.get(k, 0)
             for k in set(cur) | set(prev_counts)}
        prev_counts = cur
        ops = max(1, win_ops)
        fsyncs = d.get("fsync_calls", 0)
        logs = d.get("log_calls", 0)
        reads = max(1, win_reads)
        sig = {
            "ops": win_ops,
            "fsync_rate": fsyncs / ops,
            "coalesce_rate": (d.get("fsync_coalesced", 0) / fsyncs
                              if fsyncs else 0.0),
            "log_rate": logs / ops,
            "log_coalesce_rate": (d.get("log_coalesced", 0) / logs
                                  if logs else 0.0),
            "stall_rate": d.get("stalls", 0) / ops,
            "bypass_rate": (d.get("bypass", 0) / win_writes
                            if win_writes else 0.0),
            "staged_frac": vol.staged_frac(),
            "read_rate": win_reads / ops,
            "tier_hit_rate": ((d.get("tier_hits", 0)
                               + d.get("read_hits", 0)) / reads
                              if win_reads else 0.0),
            "scan_denial_rate": 0.0,
            "per_tenant_p99_us": {
                name: float(np.percentile(ls, 99.0))
                for name, ls in win_tenant_lats.items() if ls},
        }
        changes = autotune.observe(sig)
        if changes:
            vol.set_knobs(changes)
            knob_trace.append((t, dict(changes)))
        win_ops = win_reads = win_writes = 0
        win_tenant_lats.clear()

    for phase in phases:
        pname = phase.get("name", f"phase{len(per_phase)}")
        tenants = phase["tenants"]
        lba_dist = phase.get("lba_dist", "uniform")
        theta = phase.get("zipf_theta", 0.99)
        st_tenant: list[str] = []
        st_ops: list[np.ndarray] = []
        st_reads: list = []
        st_fsync: list[int] = []
        st_log: list[int] = []
        st_think: list[float] = []
        for ten in tenants:
            jobs = max(1, int(ten.get("jobs", 2)))
            per = max(1, int(ten["n_ops"]) // jobs)
            rfrac = float(ten.get("read_frac", 0.0))
            for _ in range(jobs):
                st_tenant.append(ten.get("name", "t0"))
                if lba_dist == "zipf":
                    st_ops.append(zipf_lba_stream(rng, per, n_lbas, theta))
                elif lba_dist == "seq":
                    base = int(rng.integers(0, n_lbas))
                    st_ops.append((base + np.arange(per)) % n_lbas)
                else:
                    st_ops.append(rng.integers(0, n_lbas, size=per))
                st_reads.append(rng.random(per) < rfrac if rfrac else None)
                st_fsync.append(int(ten.get("fsync_every", 0)))
                st_log.append(int(ten.get("log_blocks", 0)))
                st_think.append(float(ten.get("think_us", 0.0)))
        ns = len(st_tenant)
        heads = [0] * ns
        core_free = [t_phase] * ns
        completions: list[list[float]] = [[] for _ in range(ns)]
        phase_lats: list[float] = []
        t_now = t_phase
        t_done = t_phase
        while True:
            cands = []
            for s in range(ns):
                k = heads[s]
                if k >= len(st_ops[s]):
                    continue
                arrive = completions[s][k - iodepth] if k >= iodepth \
                    else t_phase
                cands.append((max(arrive, core_free[s]), s, arrive))
            if not cands:
                break
            ready, s, arrive = min(cands)
            heads[s] += 1
            start = max(t_now, ready)
            t_now = start
            if autotune is not None:
                while start >= next_ctl:
                    control_tick(next_ctl)
                    next_ctl += control_every_us
            i = heads[s] - 1
            lba = int(st_ops[s][i])
            t_proc = start + stack
            if st_reads[s] is not None and st_reads[s][i]:
                done, _src = vol.read_ex(t_proc, lba)
                win_reads += 1
            elif st_log[s] > 0:
                done = vol.log(t_proc, st_log[s])
                for k in range(st_log[s]):
                    done = vol.write(done, (lba + k) % n_lbas)
                win_writes += 1
            else:
                done = vol.write(t_proc, lba)
                win_writes += 1
            if st_fsync[s] and (i + 1) % st_fsync[s] == 0:
                done = max(done, vol.fsync(done))
            win_ops += 1
            completions[s].append(done)
            core_free[s] = done + st_think[s]
            lat = done - arrive
            phase_lats.append(lat)
            all_lats.append(lat)
            win_tenant_lats.setdefault(st_tenant[s], []).append(lat)
            t_done = max(t_done, done)
        span = max(t_done - t_phase, 1e-9)
        per_phase[pname] = {
            "ops": len(phase_lats),
            "span_us": span,
            "ops_s": len(phase_lats) / span * 1e6,
            "p99_us": (float(np.percentile(phase_lats, 99.0))
                       if phase_lats else 0.0),
        }
        t_phase = t_done
    t_done = max(t_phase, vol.flush(t_phase, sync=True))
    counts = vol.counts()
    out = {
        "policy": policy,
        "makespan_us": t_done,
        "ops": len(all_lats),
        "ops_s": len(all_lats) / max(t_done, 1e-9) * 1e6,
        "mean_us": float(np.mean(all_lats)) if all_lats else 0.0,
        "p50_us": (float(np.percentile(all_lats, 50.0))
                   if all_lats else 0.0),
        "p99_us": (float(np.percentile(all_lats, 99.0))
                   if all_lats else 0.0),
        "per_phase": per_phase,
        "counts": counts,
        "knob_trace": knob_trace,
    }
    if autotune is not None:
        out["knob_final"] = autotune.values()
        out["autotune"] = autotune.stats()
    return out


def run_aio_sim_workload(policy: str, *, n_shards: int, n_lbas: int,
                         cache_slots: int, tenants: list[dict],
                         qdepth: int = 1, n_workers: int = 8,
                         aio_workers: int | None = None,
                         stripe_blocks: int = 64, op: str = "write",
                         log_blocks: int = 4, read_frac: float = 0.0,
                         watermark: float = 1.0, seed: int = 0,
                         copy_mode: str | None = None,
                         cost: CostModel | None = None) -> dict:
    """Closed-loop async-frontend workload against a striped volume:
    the queue-depth contrast for ``benchmarks/volume_bench.py --table
    aio``.

    Each tenant is ONE submitting core driving ``SimVolume.submit`` /
    ``poll`` with a bounded in-flight window of ``qdepth`` tickets
    (submission of ticket i gates on completion of ticket i-qdepth —
    the engine's per-tenant in-flight bound).  Two effects separate
    qd=1 from qd>=8, both of which the blocking frontend forfeits:

      * **submission batching** — the per-op syscall/block-layer cost
        (``bio_stack``) amortizes over ``min(qdepth, 16)`` like libaio
        io_submit (the paper's §5.2 'others' ≈54%% applies to depth-1);
      * **overlap** — a submitted op runs on an engine dispatch core
        (``aio_workers``, default ``2 x tenants``) while the submitter
        keeps submitting, so one tenant's ops spread over the shard
        DIMM banks and the eviction pool instead of serializing on its
        core.

    ``op='write'`` submits single-block staged writes; ``op='log'``
    submits ``log_blocks``-block chained-tx logged writes (journal pass
    + staging); ``read_frac`` mixes in reads.  Deterministic in virtual
    time, same cost model as every other table.

    ``copy_mode`` (PR 7, the zero-copy contrast; ``None`` keeps the
    legacy neutral submission cost so earlier tables are unchanged):

      * ``'copy'`` — every payload-carrying submit pays the defensive
        staging snapshot (``dram_copy_4k + meta`` per block: allocate +
        memcpy) UNDER THE ENGINE LOCK, exactly where
        ``AsyncIOEngine._snapshot_locked`` runs it.  The ring lock is a
        single serial server, so at high queue depth the snapshots
        serialize across every tenant and become the frontend
        bottleneck — the copy tax submission batching cannot amortize;
      * ``'zerocopy'`` — registered-buffer pinning: the submit pays one
        ``meta`` slot-bookkeeping charge under the same lock (pin the
        buffer to the ticket) and the payload crosses the engine by
        reference.
    """
    cost = cost or CostModel()
    nt = len(tenants)
    aio_workers = 2 * nt if aio_workers is None else aio_workers
    vol = SimVolume(policy, cost, n_shards=n_shards,
                    cache_slots=cache_slots, n_workers=n_workers,
                    stripe_blocks=stripe_blocks, watermark=watermark,
                    aio_workers=max(1, aio_workers))
    rng = np.random.default_rng(seed)
    names = [t.get("name", f"t{j}") for j, t in enumerate(tenants)]
    n_ops = [int(t["n_ops"]) for t in tenants]
    lbas = [rng.integers(0, max(1, n_lbas - log_blocks), size=n)
            for n in n_ops]
    rfracs = [float(t.get("read_frac", read_frac)) for t in tenants]
    is_read = [rng.random(n) < rf if rf else None
               for n, rf in zip(n_ops, rfracs)]
    bs = 4096.0
    stack = cost.bio_stack / max(1, min(qdepth, 16))
    assert copy_mode in (None, "copy", "zerocopy"), copy_mode
    blocks_per = log_blocks if op == "log" else 1
    if copy_mode == "copy":
        # allocate + memcpy per block, under the engine lock
        xfer = (cost.dram_copy_4k + cost.meta) * blocks_per
    elif copy_mode == "zerocopy":
        xfer = cost.meta                           # pin bookkeeping only
    else:
        xfer = 0.0
    ring_lock = Bank()               # the engine lock: one serial server

    heads = [0] * nt
    core_free = [0.0] * nt           # submitting core (busy per submit)
    inflight: list[list[float]] = [[] for _ in range(nt)]  # done times
    metrics = [SimMetrics() for _ in range(nt)]
    t_done = 0.0
    while True:
        # next submit per tenant: gated on its in-flight window
        best_j, best_start = -1, float("inf")
        for j in range(nt):
            if heads[j] >= n_ops[j]:
                continue
            k = heads[j]
            gate = inflight[j][k - qdepth] if k >= qdepth else 0.0
            start = max(gate, core_free[j])
            if start < best_start:
                best_start, best_j = start, j
        if best_j < 0:
            break
        j = best_j
        k = heads[j]
        heads[j] += 1
        arrive = inflight[j][k - qdepth] if k >= qdepth else 0.0
        t_sub = best_start + stack   # submission cost on the core
        if xfer:                     # snapshot/pin under the engine lock
            t_sub = ring_lock.serve(t_sub, xfer)
        core_free[j] = t_sub         # ... and the core is free again
        lba = int(lbas[j][k])
        if is_read[j] is not None and is_read[j][k]:
            tid = vol.submit(t_sub, "read", lba)
        elif op == "log":
            tid = vol.submit(t_sub, "log", lba, n_blocks=log_blocks)
        else:
            tid = vol.submit(t_sub, "write", lba)
        done = vol.complete_time(tid)
        vol.poll(done)               # retire (completion ring drained)
        inflight[j].append(done)
        metrics[j].lat(arrive, done)
        t_done = max(t_done, done)
    t_done = max(t_done, vol.flush(t_done, sync=True))   # exit fsync
    counts = vol.counts()
    counts["makespan_us"] = int(t_done)
    if copy_mode == "copy":
        counts["staging_copies"] = sum(n_ops)
    elif copy_mode == "zerocopy":
        counts["copies_avoided"] = sum(n_ops)
    total_ops = sum(n_ops)
    blocks_per_op = log_blocks if op == "log" else 1
    per_tenant = {}
    for j in range(nt):
        span = inflight[j][-1] if inflight[j] else 0.0
        per_tenant[names[j]] = {
            "ops": len(inflight[j]),
            "ops_s": len(inflight[j]) / max(span / 1e6, 1e-9),
            "mean_us": metrics[j].mean(),
            "p9999_us": metrics[j].pct(99.99),
        }
    return {
        "policy": policy,
        "n_shards": n_shards,
        "qdepth": qdepth,
        "makespan_us": t_done,
        "ops_s": total_ops / max(t_done / 1e6, 1e-9),
        "agg_mb_s": total_ops * blocks_per_op * bs / max(t_done, 1e-9),
        "counts": counts,
        "per_tenant": per_tenant,
    }


def run_hedge_sim_workload(policy: str = "btt", *, n_shards: int = 4,
                           n_lbas: int, n_clients: int = 4,
                           n_ops: int = 4000, hedge: bool = True,
                           hedge_delay_us: float | None = None,
                           slow_shard: int | None = 0,
                           slow_factor: float = 25.0,
                           stripe_blocks: int = 64,
                           cache_slots: int = 64, seed: int = 0,
                           cost: CostModel | None = None) -> dict:
    """Closed-loop read workload against a volume with ONE limping shard
    — the tail-latency contrast for ``benchmarks/volume_bench.py --table
    hedge``.

    ``slow_shard``'s media serves every request ``slow_factor`` x slower
    (fail-slow: nothing errors, nothing times out — mean throughput
    looks fine because only ``1/n_shards`` of uniform reads land there,
    but p99 collapses to the limping device's service time).  Each
    client is one serial core issuing uniform-random reads back to back;
    with ``hedge=True`` every read goes through
    :meth:`SimVolume.hedged_read` — the replica leg fires after
    ``hedge_delay_us`` of virtual time and the first completion wins.

    The default hedge delay is ``3 x btt_read()`` — a stand-in for the
    threaded scorer's healthy-cohort-median-p99 delay: comfortably above
    an unqueued healthy read, far below the limping shard's service
    time, so healthy-shard reads almost never hedge and limping-shard
    reads always escape.  Deterministic in virtual time; the hedged
    variant's p99 vs the unhedged one is the acceptance contrast (>= 2x
    at 25x limping, gated by ``check_floors.py``)."""
    cost = cost or CostModel()
    vol = SimVolume(policy, cost, n_shards=n_shards,
                    cache_slots=cache_slots, stripe_blocks=stripe_blocks,
                    slow_shard=slow_shard, slow_factor=slow_factor)
    delay = (3.0 * cost.btt_read() if hedge_delay_us is None
             else float(hedge_delay_us))
    rng = np.random.default_rng(seed)
    lbas = rng.integers(0, n_lbas, size=n_ops)
    t_free = [0.0] * max(1, n_clients)
    m = SimMetrics()
    slow_reads = 0
    stack = cost.bio_stack               # qdepth=1: full per-op stack cost
    for k in range(n_ops):
        j = min(range(len(t_free)), key=lambda i: t_free[i])
        arrive = t_free[j]
        lba = int(lbas[k])
        if slow_shard is not None and vol._map(lba)[0] == slow_shard:
            slow_reads += 1
        if hedge:
            done, _src = vol.hedged_read(arrive + stack, lba, delay)
        else:
            done = vol.read(arrive + stack, lba)
        m.lat(arrive, done)
        t_free[j] = done
    t_done = max(t_free)
    counts = vol.counts()
    counts["slow_shard_reads"] = slow_reads
    fired = counts.get("hedges_fired", 0)
    won = counts.get("hedges_won", 0)
    cancelled = counts.get("hedges_cancelled", 0)
    assert fired == won + cancelled, (fired, won, cancelled)
    return {
        "policy": policy,
        "n_shards": n_shards,
        "hedge": hedge,
        "hedge_delay_us": round(delay, 3),
        "slow_shard": slow_shard,
        "slow_factor": slow_factor if slow_shard is not None else 1.0,
        "n_ops": n_ops,
        "makespan_us": t_done,
        "ops_s": n_ops / max(t_done / 1e6, 1e-9),
        "mean_us": m.mean(),
        "p50_us": m.pct(50),
        "p99_us": m.pct(99),
        "p999_us": m.pct(99.9),
        "counts": counts,
    }


def run_transit_sim_workload(*, n_pages: int, page_kb: int = 16,
                             fused: bool = True, n_cores: int = 2,
                             cost: CostModel | None = None) -> dict:
    """Virtual-time model of the KV spill codec (the fused-transit
    contrast for ``benchmarks/volume_bench.py --table zerocopy``).

    Each page transits HBM -> host tier.  The THREE-PASS baseline walks
    the page once per stage, exactly like the pre-fusion code path:

      1. gather+quantize kernel pass   (``dram_copy_4k`` per 4 KB),
      2. host checksum walk over the packed bytes (1/4 size, int8),
      3. copy-out pass staging the payload for the eviction DMA.

    The FUSED path (``gather_quantize_crc``) does pack + checksum +
    copy-out in ONE traversal while the page is in VMEM.  Both variants
    then pay the same eviction-pool DMA (``pmem_write_4k`` on the
    interleaved banks, 1/4 size — int8) — fusion removes memory passes,
    not media time.  Codec passes run on ``n_cores`` eviction cores
    (earliest-free dispatch, same as the aio frontend)."""
    cost = cost or CostModel()
    media = Media(cost)
    cores = [Bank() for _ in range(max(1, n_cores))]
    per4k = page_kb / 4.0
    pass_us = cost.dram_copy_4k * per4k          # one full-page traversal
    packed4k = per4k / 4.0                       # int8 payload, 1/4 size
    if fused:
        codec_us = pass_us + cost.meta           # one pass + crc fold
        passes = 1
    else:
        # pack pass + checksum walk (packed size) + copy-out pass
        codec_us = pass_us + cost.dram_copy_4k * packed4k + pass_us
        passes = 3
    t_done = 0.0
    for _ in range(n_pages):
        core = min(cores, key=lambda b: b.free_at)
        t_codec = core.serve(core.free_at, codec_us)
        t_done = max(t_done, media.write(t_codec,
                                         cost.pmem_write_4k * packed4k))
    return {
        "fused": fused,
        "n_pages": n_pages,
        "page_kb": page_kb,
        "passes_per_page": passes,
        "makespan_us": t_done,
        "pages_s": n_pages / max(t_done / 1e6, 1e-9),
        "mb_s": n_pages * page_kb / 1024.0 / max(t_done / 1e6, 1e-9),
    }


def run_kv_paging_sim_workload(*, n_sessions: int, hbm_pages: int = 16,
                               host_pages: int = 32,
                               pages_per_session: int = 4,
                               page_blocks: int = 4,
                               shared_pages: int = 0,
                               tokens_per_turn: int = 16,
                               rounds: int = 2, decode_us: float = 40.0,
                               prefetch_depth: int = 4,
                               n_shards: int = 4, cache_slots: int = 512,
                               aio_workers: int = 4,
                               cost: CostModel | None = None) -> dict:
    """Virtual-time model of KV paging past DRAM (the sessions-sweep
    driver for ``benchmarks/serve_paged.py``).

    ``n_sessions`` chat sessions of ``pages_per_session`` KV pages each
    take ``rounds`` round-robin decode turns on ONE accelerator (a
    serial :class:`Bank`; a turn is ``tokens_per_turn x decode_us``).
    The HBM pool holds ``hbm_pages // pages_per_session`` resident
    sessions; activating a session past that evicts the least-recently
    decoded one through the tier walk the threaded cache runs:

      * HBM -> host: one fused codec pass per page (``dram_copy_4k`` per
        4 KB + ``meta``) on the eviction cores — off the decode path;
      * host overflow -> volume: the oldest host page spills as ONE
        chained ``log`` record of ``page_blocks`` blocks through
        ``SimVolume.submit`` (the async frontend: spill IO overlaps
        decode).  ``shared_pages`` of every session are a common prefix:
        content-addressed records mean the first spill writes and the
        rest are dedup refcount bumps — no IO;
      * volume -> HBM: restore reads one ticket per block.  With
        ``prefetch_depth > 0`` the reads for the next D scheduled
        sessions are issued when a turn STARTS decoding, so the volume
        round trip overlaps the running turn (decode-ahead); depth 0 is
        the synchronous contrast — activation stalls on the reads.

    Deterministic in virtual time; with ``n_sessions`` at the resident
    bound the tier machinery never engages and the run is pure decode
    (the degradation baseline)."""
    cost = cost or CostModel()
    assert pages_per_session >= 1 and shared_pages <= pages_per_session
    resident_cap = max(1, hbm_pages // pages_per_session)
    vol = SimVolume("caiti", cost, n_shards=n_shards,
                    cache_slots=cache_slots,
                    aio_workers=max(1, aio_workers))
    decode = Bank()                       # the accelerator, serial
    evict_cores = [Bank(), Bank()]        # fused-codec page-out cores
    page_us = cost.dram_copy_4k * page_blocks + cost.meta
    # page content keys: shared prefix pages dedup across sessions
    def key(s: int, p: int):
        return ("sh", p) if p < shared_pages else ("pv", s, p)

    loc: dict[tuple, str] = {}            # (s, p) -> hbm | host | vol
    host_fifo: list[tuple] = []           # (s, p) spill order
    resident: list[int] = []              # session ids, LRU order
    records: dict = {}    # key -> [lba, refs, done_t] live volume records
    free_lbas: list[int] = []
    next_lba = [0]
    pf_ready: dict[tuple, float] = {}     # (s, p) -> prefetched data time
    counts = defaultdict(int)

    def spill_page(t: float, s: int, p: int) -> None:
        k = key(s, p)
        rec = records.get(k)
        if rec is not None:
            rec[1] += 1
            counts["dedup_hits"] += 1
        else:
            lba = free_lbas.pop() if free_lbas else next_lba[0]
            if not free_lbas or lba == next_lba[0]:
                next_lba[0] = max(next_lba[0], lba + page_blocks)
            tid = vol.submit(t, "log", lba, n_blocks=page_blocks)
            records[k] = [lba, 1, vol.complete_time(tid)]
            vol.poll(vol.complete_time(tid))
            counts["spills"] += 1
            counts["spill_blocks"] += page_blocks
        loc[(s, p)] = "vol"

    def evict_session(t: float, victim: int) -> float:
        t_done = t
        for p in range(pages_per_session):
            if loc.get((victim, p)) != "hbm":
                continue
            core = min(evict_cores, key=lambda b: b.free_at)
            t_done = max(t_done, core.serve(max(t, core.free_at), page_us))
            loc[(victim, p)] = "host"
            host_fifo.append((victim, p))
            counts["hbm_evictions"] += 1
        while sum(1 for v in loc.values() if v == "host") > host_pages:
            s2, p2 = host_fifo.pop(0)
            if loc.get((s2, p2)) != "host":
                continue
            spill_page(t_done, s2, p2)
        return t_done

    def issue_reads(t: float, s: int) -> None:
        for p in range(pages_per_session):
            if loc.get((s, p)) != "vol" or (s, p) in pf_ready:
                continue
            k = key(s, p)
            lba = records[k][0]
            done = t
            for b in range(page_blocks):     # linked read chain
                tid = vol.submit(t, "read", lba + b)
                done = max(done, vol.complete_time(tid))
                vol.poll(vol.complete_time(tid))
            pf_ready[(s, p)] = done

    def activate(t: float, s: int, prefetched: bool) -> float:
        """Returns the time the session's pages are all HBM-resident."""
        if s in resident:
            resident.remove(s)
            resident.append(s)
            return t
        while len(resident) >= resident_cap:
            t = evict_session(t, resident.pop(0))
        ready = t
        if any(loc.get((s, p)) == "vol" for p in range(pages_per_session)):
            issue_reads(t, s)   # no-op for pages already in flight;
            for p in range(pages_per_session):  # sync pages start NOW
                if loc.get((s, p)) != "vol":
                    continue
                done = pf_ready.pop((s, p))
                if done <= t and prefetched:
                    counts["prefetch_hits"] += 1
                ready = max(ready, done)
                counts["restores_vol"] += 1
                k = key(s, p)
                rec = records[k]
                rec[1] -= 1
                if rec[1] == 0:
                    free_lbas.append(rec[0])
                    del records[k]
                loc[(s, p)] = "host"        # restored payload, unpack next
        for p in range(pages_per_session):
            where = loc.get((s, p))
            if where == "host":
                ready += page_us            # dequant pass on the way in
                counts["restores_host"] += 1
            loc[(s, p)] = "hbm"
        resident.append(s)
        return ready

    schedule = [s for _r in range(rounds) for s in range(n_sessions)]
    prefetched: set[int] = set()
    tokens = 0
    for i, s in enumerate(schedule):
        t0 = decode.free_at
        t_ready = activate(t0, s, s in prefetched)
        prefetched.discard(s)
        t_start = max(t_ready, decode.free_at)
        if prefetch_depth > 0:
            # decode-ahead: reads for the next D distinct sessions
            nxt = []
            for s2 in schedule[i + 1:]:
                if s2 not in nxt and s2 != s:
                    nxt.append(s2)
                if len(nxt) >= prefetch_depth:
                    break
            for s2 in nxt:
                if any(loc.get((s2, p)) == "vol"
                       for p in range(pages_per_session)):
                    issue_reads(t_start, s2)
                    prefetched.add(s2)
                    counts["prefetch_issued"] += 1
        decode.serve(t_start, tokens_per_turn * decode_us)
        tokens += tokens_per_turn
    counts["prefetch_wasted"] = len(pf_ready)   # issued, never consumed
    makespan = decode.free_at
    return {
        "n_sessions": n_sessions,
        "resident_cap": resident_cap,
        "rounds": rounds,
        "tokens": tokens,
        "makespan_us": makespan,
        "tokens_s": tokens / max(makespan / 1e6, 1e-9),
        "prefetch_depth": prefetch_depth,
        "shared_pages": shared_pages,
        **{k: int(counts[k]) for k in
           ("spills", "spill_blocks", "dedup_hits", "hbm_evictions",
            "restores_host", "restores_vol", "prefetch_issued",
            "prefetch_hits", "prefetch_wasted")},
        "live_records": len(records),
    }


# ---------------------------------------------------------------- cluster
class SimCluster:
    """Virtual-time model of the distributed cluster volume
    (``repro.cluster``): ``n_nodes`` member :class:`SimVolume`\\ s, each
    behind a serial NIC :class:`Bank`, with chunk chains mapped by the
    REAL :class:`repro.cluster.placement.PlacementPolicy` (imported at
    call time, like :class:`SimReadTier`) — the simulator exercises the
    exact placement the threaded cluster runs.

    Replication modes (the acceptance contrast):

      ``pipelined``  chain replication with cut-through forwarding: the
                     client uplinks the payload ONCE to the primary; hop
                     j starts receiving one block behind hop j-1, so K
                     transfers overlap to within a block and node writes
                     overlap upstream transfers.  Acks ripple tail to
                     head concurrently with upstream work — one final
                     ack latency reaches the client;
      ``serial``     client-fanout replication: the client sends the
                     payload to every replica itself (K uplinks
                     serialize on its NIC) and each replica acks
                     directly — the flat-replication baseline.

    NICs are serial servers, so concurrent tenants contend for node
    ingress exactly like submitting cores contend for shard DIMM banks.
    A node kill mid-workload drops it from every chain (writes fail over
    to the surviving members); :meth:`rereplicate` runs the regeneration
    storm — survivor media read, one-block transfer, target write per
    lost block — in virtual time, chosen by ``placement.replacement``.
    """

    def __init__(self, policy: str, cost: CostModel, *, n_nodes: int,
                 replication_k: int = 2, chunk_blocks: int = 64,
                 cache_slots: int = 4096, n_workers: int = 4,
                 n_shards: int = 2, stripe_blocks: int = 16,
                 racks: int = 2, placement: str = "spread",
                 net_latency_us: float = 5.0,
                 net_mb_s: float = 3000.0) -> None:
        from repro.cluster.placement import (NodeInfo,   # no import cycle
                                             PlacementPolicy)  # at call time
        self.cost = cost
        self.vols = [SimVolume(policy, cost, n_shards=n_shards,
                               cache_slots=cache_slots, n_workers=n_workers,
                               stripe_blocks=stripe_blocks)
                     for _ in range(n_nodes)]
        self.nics = [Bank() for _ in range(n_nodes)]
        infos = [NodeInfo(f"node{i}", rack=i % max(1, racks))
                 for i in range(n_nodes)]
        self.place = PlacementPolicy(infos, k=replication_k,
                                     policy=placement)
        self.lat = net_latency_us
        self.bw = net_mb_s                 # MB/s == bytes/us (exact)
        self.chunk_blocks = chunk_blocks
        self.chains: dict[int, list[int]] = {}
        self.alive = [True] * n_nodes
        self._written: dict[int, set] = defaultdict(set)
        self.ccounts: dict = defaultdict(int)
        self.bs = 4096.0

    # -------------------------------------------------------------- mapping
    def _chain(self, chunk: int) -> list[int]:
        ch = self.chains.get(chunk)
        if ch is None:
            elig = [i for i in range(len(self.vols)) if self.alive[i]]
            ch = self.place.assign(chunk, self.chunk_blocks,
                                   eligible=elig or None)
            self.chains[chunk] = ch
        return ch

    def _span(self, nbytes: float) -> float:
        return nbytes / self.bw

    # ------------------------------------------------------------------ I/O
    def write(self, t: float, client_nic: Bank, lba: int, n_blocks: int,
              mode: str = "pipelined") -> float:
        """One replicated logical write of ``n_blocks`` consecutive
        blocks (must stay inside one chunk); returns the ack time at the
        client."""
        chunk = lba // self.chunk_blocks
        chain = [i for i in self._chain(chunk) if self.alive[i]]
        assert chain, "no live replica for chunk"
        nbytes = n_blocks * self.bs
        w = self._span(nbytes)             # full-payload transfer span
        b = self._span(self.bs)            # one-block span (cut-through)
        self.ccounts["cluster_writes"] += 1
        self.ccounts["net_bytes"] += int(nbytes) * len(chain)
        for i in range(n_blocks):
            self._written[chunk].add(lba + i)
        if mode == "pipelined":
            depart = client_nic.serve(t, w)            # ONE client uplink
            arr = self.nics[chain[0]].serve(depart - w + b + self.lat, w)
            done = 0.0
            for j, ni in enumerate(chain):
                if j > 0:                  # hop j trails hop j-1 by a block
                    arr = self.nics[ni].serve(arr - w + b + self.lat, w)
                end = arr
                for i in range(n_blocks):
                    end = self.vols[ni].write(end, lba + i)
                done = max(done, end)
            return done + self.lat         # tail ack ripples concurrently
        # serial: K uplinks on the client NIC, per-replica direct acks
        done = 0.0
        for ni in chain:
            depart = client_nic.serve(t, w)
            arr = self.nics[ni].serve(depart - w + b + self.lat, w)
            end = arr
            for i in range(n_blocks):
                end = self.vols[ni].write(end, lba + i)
            done = max(done, end + self.lat)
        return done

    def read(self, t: float, client_nic: Bank, lba: int) -> float:
        chain = [i for i in self._chain(lba // self.chunk_blocks)
                 if self.alive[i]]
        assert chain, "no live replica for chunk"
        ni = chain[0]
        end = self.vols[ni].read(t + self.lat, lba)
        self.ccounts["net_bytes"] += int(self.bs)
        return client_nic.serve(end + self.lat, self._span(self.bs))

    # ------------------------------------------------------------- failures
    def kill(self, node: int) -> None:
        self.alive[node] = False
        self.ccounts["nodes_killed"] += 1

    def rereplicate(self, t: float) -> float:
        """The regeneration storm after a death: every written chunk that
        lost a chain member is copied — survivor media read, one-block
        transfer, target write — onto ``placement.replacement``'s pick.
        Returns the storm's completion time."""
        end = t
        for chunk, chain in sorted(self.chains.items()):
            for dead in [i for i in chain if not self.alive[i]]:
                alive = [i for i in range(len(self.vols)) if self.alive[i]]
                target = self.place.replacement(chain, dead, alive)
                src = next((i for i in chain
                            if i != dead and self.alive[i]), None)
                if target is None or src is None:
                    self.ccounts["rereplication_unplaceable"] += 1
                    continue
                tt = t
                lbas = sorted(self._written.get(chunk, ()))
                for lba in lbas:
                    r = self.vols[src].read(tt, lba)
                    a = self.nics[target].serve(r + self.lat,
                                                self._span(self.bs))
                    tt = self.vols[target].write(a, lba)
                chain[chain.index(dead)] = target
                self.place.transfer(dead, target, len(lbas))
                self.ccounts["chunks_repaired"] += 1
                self.ccounts["rereplicated_blocks"] += len(lbas)
                self.ccounts["net_bytes"] += int(len(lbas) * self.bs)
                end = max(end, tt)
        return end

    def counts(self) -> dict:
        agg: dict = defaultdict(int)
        for v in self.vols:
            for k, x in v.counts().items():
                agg[k] += x
        for k, x in self.ccounts.items():
            agg[k] += x
        return dict(agg)


def run_cluster_sim_workload(policy: str = "btt", *, n_nodes: int = 4,
                             replication_k: int = 2, n_lbas: int,
                             chunk_blocks: int = 64,
                             cache_slots: int = 4096,
                             tenants: list[dict], n_blocks: int = 8,
                             qdepth: int = 4, mode: str = "pipelined",
                             placement: str = "spread", racks: int = 2,
                             net_latency_us: float = 5.0,
                             net_mb_s: float = 3000.0,
                             read_frac: float = 0.0,
                             kill_node: int | None = None,
                             kill_at_frac: float = 0.5,
                             n_workers: int = 4, n_shards: int = 2,
                             stripe_blocks: int = 16, seed: int = 0,
                             cost: CostModel | None = None) -> dict:
    """Closed-loop replicated-write workload against a
    :class:`SimCluster` — the ``--table cluster`` engine.

    Each tenant is one serial client core with its own NIC and a bounded
    window of ``qdepth`` outstanding replicated writes (submission of
    request i gates on completion of request i-qdepth).  Addresses are
    chunk-aligned groups of ``n_blocks`` so every logical write stays
    inside one chain — the whole-object-atomic envelope the threaded
    cluster enforces.

    ``mode`` selects the replication discipline (``pipelined`` chain vs
    ``serial`` client-fanout — see :class:`SimCluster`); the ops/s ratio
    between the two at 4 nodes / K=2 is the paper-style acceptance
    contrast (>= 1.5x).

    ``kill_node`` fail-stops that node once ``kill_at_frac`` of all ops
    have completed: in-flight and subsequent writes fail over to the
    surviving chain members, and the re-replication storm
    (:meth:`SimCluster.rereplicate`) runs to completion in virtual time
    — its span and block count are reported in ``counts``.
    """
    cost = cost or CostModel()
    cl = SimCluster(policy, cost, n_nodes=n_nodes,
                    replication_k=replication_k, chunk_blocks=chunk_blocks,
                    cache_slots=cache_slots, n_workers=n_workers,
                    n_shards=n_shards, stripe_blocks=stripe_blocks,
                    racks=racks, placement=placement,
                    net_latency_us=net_latency_us, net_mb_s=net_mb_s)
    rng = np.random.default_rng(seed)
    nt = len(tenants)
    names = [t.get("name", f"t{j}") for j, t in enumerate(tenants)]
    n_ops = [int(t["n_ops"]) for t in tenants]
    n_chunks = max(1, n_lbas // chunk_blocks)
    groups = max(1, chunk_blocks // n_blocks)
    lbas = [rng.integers(0, n_chunks, size=n) * chunk_blocks
            + rng.integers(0, groups, size=n) * n_blocks
            for n in n_ops]
    is_read = [rng.random(n) < read_frac if read_frac else None
               for n in n_ops]
    client_nics = [Bank() for _ in range(nt)]
    stack = cost.bio_stack / max(1, min(qdepth, 16))
    total = sum(n_ops)
    kill_at = int(total * kill_at_frac) if kill_node is not None else -1

    heads = [0] * nt
    core_free = [0.0] * nt
    inflight: list[list[float]] = [[] for _ in range(nt)]
    metrics = [SimMetrics() for _ in range(nt)]
    t_done, n_done = 0.0, 0
    storm_span = 0.0
    while True:
        best_j, best_start = -1, float("inf")
        for j in range(nt):
            if heads[j] >= n_ops[j]:
                continue
            k = heads[j]
            gate = inflight[j][k - qdepth] if k >= qdepth else 0.0
            start = max(gate, core_free[j])
            if start < best_start:
                best_start, best_j = start, j
        if best_j < 0:
            break
        j = best_j
        k = heads[j]
        heads[j] += 1
        arrive = inflight[j][k - qdepth] if k >= qdepth else 0.0
        lba = int(lbas[j][k])
        t_sub = best_start + stack       # submit cost on the client core;
        core_free[j] = t_sub             # the NIC serializes the uplinks
        if is_read[j] is not None and is_read[j][k]:
            done = cl.read(t_sub, client_nics[j], lba)
        else:
            done = cl.write(t_sub, client_nics[j], lba, n_blocks,
                            mode=mode)
        inflight[j].append(done)
        metrics[j].lat(arrive, done)
        t_done = max(t_done, done)
        n_done += 1
        if n_done == kill_at and cl.alive[kill_node]:
            cl.kill(kill_node)
            storm_end = cl.rereplicate(t_done)
            storm_span = storm_end - t_done
            t_done = max(t_done, storm_end)
    counts = cl.counts()
    counts["makespan_us"] = int(t_done)
    counts["storm_span_us"] = int(storm_span)
    per_tenant = {}
    for j in range(nt):
        span = inflight[j][-1] if inflight[j] else 0.0
        per_tenant[names[j]] = {
            "ops": len(inflight[j]),
            "ops_s": len(inflight[j]) / max(span / 1e6, 1e-9),
            "mean_us": metrics[j].mean(),
            "p9999_us": metrics[j].pct(99.99),
        }
    return {
        "policy": policy,
        "mode": mode,
        "n_nodes": n_nodes,
        "replication_k": replication_k,
        "placement": placement,
        "makespan_us": t_done,
        "ops_s": total / max(t_done / 1e6, 1e-9),
        "agg_mb_s": total * n_blocks * 4096.0 / max(t_done, 1e-9),
        "rack_diversity": (
            sum(cl.place.rack_diversity(c) for c in cl.chains.values())
            / max(1, len(cl.chains))),
        "balance": cl.place.balance(),
        "counts": counts,
        "per_tenant": per_tenant,
    }
