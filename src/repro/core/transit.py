"""Caiti's algorithm generalized to arbitrary producer→sink transit.

The checkpoint engine (and any other host-side pipeline) uses this class to
get the paper's two policies without caring about blocks/lbas:

  * **eager eviction**  — every item put into the staging buffer is handed to
    a background pool immediately; ``flush()`` (the fsync analogue) therefore
    finds the buffer nearly empty.
  * **conditional bypass** — when staging RAM is exhausted, ``put`` invokes
    the sink synchronously instead of blocking behind the drain.

The staging capacity is measured in bytes so the engine can bound host-RAM
usage precisely (the 'DRAM cache' of the paper).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

from .metrics import Metrics


class TransitBuffer:
    def __init__(self, sink: Callable[[object], None],
                 capacity_bytes: int = 256 << 20, n_workers: int = 2,
                 eager: bool = True, bypass: bool = True,
                 metrics: Metrics | None = None, admission=None) -> None:
        self.sink = sink
        self.capacity = capacity_bytes
        self.eager = eager
        self.bypass = bypass
        # optional repro.volume.AdmissionPolicy: when the unified policy
        # says the system is over its aggregate watermark, a put bypasses
        # staging even though THIS buffer still has room — the same
        # global conditional-bypass rule the block-level caches follow
        self.admission = admission
        self.metrics = metrics or Metrics()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._staged_bytes = 0
        self._enqueued = 0
        self._completed = 0
        self._errors: list[BaseException] = []
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._stop = False
        self._workers = [threading.Thread(target=self._run, daemon=True,
                                          name=f"transit-{i}")
                         for i in range(n_workers)]
        for w in self._workers:
            w.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            payload, nbytes = item
            try:
                self.sink(payload)
            except BaseException as e:  # surfaced at flush()
                with self._lock:
                    self._errors.append(e)
            with self._cond:
                self._staged_bytes -= nbytes
                self._completed += 1
                self._cond.notify_all()

    def put(self, payload, nbytes: int) -> str:
        """Stage one item. Returns 'staged' or 'bypass'."""
        globally_full = (self.bypass and self.admission is not None
                         and self.admission.should_bypass_write())
        with self._lock:
            full = globally_full \
                or self._staged_bytes + nbytes > self.capacity
            if not full:
                self._staged_bytes += nbytes
                self._enqueued += 1
        if full:
            if self.bypass:
                # conditional bypass: sink synchronously, skip staging
                with self.metrics.timer("conditional_bypass"):
                    self.sink(payload)
                self.metrics.bump("bypass_writes")
                return "bypass"
            # no-bypass: block until the drain makes room (staging behaviour)
            with self._cond:
                while self._staged_bytes + nbytes > self.capacity:
                    self._cond.wait(timeout=0.5)
                self._staged_bytes += nbytes
                self._enqueued += 1
        if self.eager:
            self._q.put((payload, nbytes))          # eager eviction
        else:
            with self._lock:
                self._lazy = getattr(self, "_lazy", [])
                self._lazy.append((payload, nbytes))
        return "staged"

    def flush(self) -> None:
        """fsync analogue: wait until everything staged so far is sunk."""
        with self.metrics.timer("cache_flush"):
            if not self.eager:
                with self._lock:
                    lazy = getattr(self, "_lazy", [])
                    self._lazy = []
                for item in lazy:
                    self._q.put(item)
            with self._cond:
                target = self._enqueued
                while self._completed < target:
                    self._cond.wait(timeout=0.5)
                if self._errors:
                    err = self._errors[0]
                    self._errors.clear()
                    raise err

    def staged_bytes(self) -> int:
        with self._lock:
            return self._staged_bytes

    def close(self) -> None:
        self.flush()
        for _ in self._workers:
            self._q.put(None)
        for w in self._workers:
            w.join(timeout=2.0)
