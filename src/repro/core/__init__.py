"""repro.core — the paper's contribution: I/O transit caching (Caiti) over a
PMem block device with block-level write atomicity (BTT).

Public API:
    make_device(policy, ...)      — full device stacks ('caiti', 'btt', 'lru', ...)
    BTT, PMemSpace, LatencyModel  — substrate pieces
    CaitiCache, CaitiConfig       — the transit cache itself
    TransitBuffer                 — Caiti's policies for arbitrary sinks (ckpt engine)
    Bio, BioFlags, fsync_bio      — block-I/O request model
"""
from .bio import Bio, BioFlags, BioOp, SUCCESS, EIO, fsync_bio, preflush_bio
from .btt import BTT
from .cache import CaitiCache, CaitiConfig, FREE, PENDING, VALID, EVICTING
from .device import BlockDevice, make_device, POLICIES
from .metrics import Metrics, ShardScorer, CATEGORIES
from .pmem import PMemSpace, LatencyModel, NO_LATENCY, SimulatedCrash
from .policies import CoActiveCache, LRUCache, PMBD70Cache, PMBDCache
from .transit import TransitBuffer

__all__ = [
    "Bio", "BioFlags", "BioOp", "SUCCESS", "EIO", "fsync_bio", "preflush_bio",
    "BTT", "CaitiCache", "CaitiConfig", "FREE", "PENDING", "VALID", "EVICTING",
    "BlockDevice", "make_device", "POLICIES", "Metrics", "ShardScorer",
    "CATEGORIES",
    "PMemSpace", "LatencyModel", "NO_LATENCY", "SimulatedCrash",
    "CoActiveCache", "LRUCache", "PMBD70Cache", "PMBDCache", "TransitBuffer",
]
