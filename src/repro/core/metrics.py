"""Per-category time accounting used for the paper's Fig. 6 breakdown.

Categories follow the paper's naming exactly:
  cache_metadata        — set lookup / slot alloc / state transitions
  cache_write_only      — the DRAM memcpy into a slot (hit or free slot)
  cache_eviction_and_write — a *stalled* write: evict-on-critical-path + write
  conditional_bypass    — direct BTT write because cache is full
  wbq_enqueue           — putting the slot on the write-back queue
  cache_flush           — serving PREFLUSH/FUA/fsync drains
  others                — everything else on the critical path

Read-path counters (the layered read stack of PR 2) are plain events on
``count`` — ``read_path()`` summarizes where reads were served from:
  read_hits             — transit-cache (staged write) hits
  read_tier_hits        — clean DRAM read-tier hits
  read_tier_fills       — tier populations from a backend read miss
  read_misses           — full BTT/PMem round trips
  verify_failures       — primary copies failing crc verification
  degraded_reads        — reads served from a replica instead
  verify_races          — all copies agreed, only the ledger disagreed
                          (a mid-flight write, not corruption)
  unrecoverable_reads   — no copy matched the ledger (surfaced primary)
  resync_repairs        — divergent copies rewritten by the resyncer
  tier_fill_bypassed    — read-miss fills denied by the admission layer
                          (sequential-scan bypass: the scan must not
                          flush the tier's hot set)

Commit-path counters (the transactional write pipeline of PR 3, batched
log pipeline of PR 4) live on ``count`` as well — ``commit_path()``
summarizes them:
  chain_txs             — chained-journal links logged (whole-object
                          atomicity for >span logical writes)
  group_commits         — leader-executed fsync checkpoints
  group_commit_waiters  — fsync calls that coalesced onto a leader's
                          commit instead of paying their own drain +
                          superblock pass
  log_batches           — LogBatcher flushes (one _txlock acquisition +
                          one batched slot-shard journal pass each)
  log_batch_links       — chain links written through batched passes
  log_batch_coalesced   — log()/write_multi chains that rode another
                          caller's batch instead of paying their own pass

Per-tenant counters are bumped under ``"<event>::<tenant>"`` keys and
collected with :meth:`Metrics.per_tenant` — the volume records
``wfq_vbytes::<tenant>``, the tier-aware WFQ virtual time (priced bytes)
each tenant has been charged across reads, writes and batched journal
traffic.

Service-time EWMAs (fail-slow groundwork): :meth:`Metrics.observe`
tracks a per-key exponentially weighted moving average of service
nanoseconds (plus count and max) under ``svc::<where>`` keys — the
striped volume observes ``svc::shard<i>``, the async engine
``svc::aio::<op>``, the cluster layer ``svc::node<i>``.
:meth:`Metrics.per_node` collects them, and both the volume and cluster
``scrub`` outputs surface the table: a limping shard/node (fail-slow,
not fail-stop) shows up as one EWMA drifting away from its peers long
before any heartbeat trips.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from contextlib import contextmanager

CATEGORIES = (
    "cache_metadata",
    "cache_write_only",
    "cache_eviction_and_write",
    "conditional_bypass",
    "wbq_enqueue",
    "cache_flush",
    "others",
)

READ_COUNTERS = (
    "read_hits",
    "read_tier_hits",
    "read_tier_fills",
    "read_misses",
    "verify_failures",
    "degraded_reads",
    "verify_races",
    "unrecoverable_reads",
    "resync_repairs",
    "tier_fill_bypassed",
)

COMMIT_COUNTERS = (
    "chain_txs",
    "group_commits",
    "group_commit_waiters",
    "log_batches",
    "log_batch_links",
    "log_batch_coalesced",
)

# Zero-copy data plane counters (PR 7) — bumped by the async engine's
# registered-buffer pool / linked-SQE machinery and by the fused transit
# kernel's callers; ``zerocopy_path()`` summarizes them:
#   copies_avoided       — submits that pinned a registered buffer (or
#                          landed a read directly in one) instead of
#                          taking a staging snapshot
#   bytes_pinned         — payload bytes that crossed the engine pinned
#   staging_copies       — defensive snapshots (unregistered mutable
#                          payloads + copy-on-evict steals)
#   staging_copy_bytes   — bytes those snapshots copied
#   links_submitted      — linked-SQE tickets (chained to a parent)
#   link_cancelled       — dependents failed with ECANCELED by a parent
#   link_depth_max       — deepest chain seen
#   fused_kernel_passes  — fused transit-kernel launches (one VMEM pass
#                          doing gather/scatter + int8 codec + checksum)
#   fused_kernel_bytes   — packed payload bytes those passes moved
#   transit_crc_errors   — restore checksums that failed verification
ZEROCOPY_COUNTERS = (
    "copies_avoided",
    "bytes_pinned",
    "staging_copies",
    "staging_copy_bytes",
    "links_submitted",
    "link_cancelled",
    "link_depth_max",
    "fused_kernel_passes",
    "fused_kernel_bytes",
    "transit_crc_errors",
)


#: EWMA smoothing for :meth:`Metrics.observe` — ~the last 10-ish
#: observations dominate, so a shard/node turning slow moves its average
#: within tens of ops instead of being diluted by history
EWMA_ALPHA = 0.2


class Metrics:
    """Thread-safe counters + nanosecond timers, cheap enough for hot paths."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.ns = defaultdict(int)        # category -> total ns
        self.count = defaultdict(int)     # category/event -> occurrences
        self.latencies_ns: list[int] = [] # per-request response times
        self.record_latencies = False
        # key -> [ewma_ns, n, max_ns] service-time summaries (observe())
        self._svc: dict[str, list] = {}

    @contextmanager
    def timer(self, category: str):
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            dt = time.perf_counter_ns() - t0
            with self._lock:
                self.ns[category] += dt
                self.count[category] += 1

    def add_ns(self, category: str, ns: int) -> None:
        with self._lock:
            self.ns[category] += ns
            self.count[category] += 1

    def bump(self, event: str, n: int = 1) -> None:
        with self._lock:
            self.count[event] += n

    def record_latency(self, ns: int) -> None:
        if self.record_latencies:
            with self._lock:
                self.latencies_ns.append(ns)

    def observe(self, key: str, ns: int) -> None:
        """Fold one service time (nanoseconds) into ``key``'s EWMA.
        Keys follow the per-tenant convention (``svc::shard3``,
        ``svc::node1``, ``svc::aio::write_multi``) so :meth:`per_node`
        can collect a whole family at once."""
        with self._lock:
            st = self._svc.get(key)
            if st is None:
                self._svc[key] = [float(ns), 1, ns]
            else:
                st[0] += EWMA_ALPHA * (ns - st[0])
                st[1] += 1
                if ns > st[2]:
                    st[2] = ns

    def per_node(self, prefix: str = "svc") -> dict[str, dict]:
        """Service-time summaries observed under ``f"{prefix}::..."``:
        suffix -> ``{"ewma_us", "n", "max_us"}``.  The fail-slow detector
        input: one EWMA drifting off its peers is a limping shard/node."""
        pre = prefix + "::"
        with self._lock:
            return {k[len(pre):]: {"ewma_us": st[0] / 1e3, "n": st[1],
                                   "max_us": st[2] / 1e3}
                    for k, st in self._svc.items() if k.startswith(pre)}

    # -- report helpers -----------------------------------------------------
    def breakdown(self) -> dict[str, float]:
        """Fractional time per category (paper Fig. 6a)."""
        total = sum(self.ns[c] for c in CATEGORIES) or 1
        return {c: self.ns[c] / total for c in CATEGORIES}

    def read_path(self) -> dict[str, float]:
        """Read-path summary: every counter plus the fraction of reads
        served without touching the backend (transit or tier hit)."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in READ_COUNTERS}
        served = out["read_hits"] + out["read_tier_hits"] + out["read_misses"]
        out["dram_hit_rate"] = ((out["read_hits"] + out["read_tier_hits"])
                                / served if served else 0.0)
        return out

    def commit_path(self) -> dict[str, float]:
        """Commit-path summary: chained-tx, group-commit and batched-log
        counters plus the coalescing rates (the fraction of fsync calls
        that rode a leader's commit, and of chains that rode another
        caller's log batch)."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in COMMIT_COUNTERS}
        calls = out["group_commits"] + out["group_commit_waiters"]
        out["coalesce_rate"] = (out["group_commit_waiters"] / calls
                                if calls else 0.0)
        chains = out["log_batches"] + out["log_batch_coalesced"]
        out["log_coalesce_rate"] = (out["log_batch_coalesced"] / chains
                                    if chains else 0.0)
        return out

    def zerocopy_path(self) -> dict[str, float]:
        """Zero-copy data-plane summary: pin/snapshot/link/fused-kernel
        counters plus ``pin_rate`` — the fraction of payload-carrying
        submits that crossed the engine without a copy."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in ZEROCOPY_COUNTERS}
        moved = out["copies_avoided"] + out["staging_copies"]
        out["pin_rate"] = out["copies_avoided"] / moved if moved else 0.0
        return out

    def per_tenant(self, prefix: str) -> dict[str, int]:
        """Collect per-tenant counters bumped as ``f"{prefix}::{t}"``
        (e.g. ``per_tenant('wfq_vbytes')`` -> tenant -> priced bytes)."""
        pre = prefix + "::"
        with self._lock:
            return {k[len(pre):]: v for k, v in self.count.items()
                    if k.startswith(pre)}

    def percentile_us(self, p: float) -> float:
        if not self.latencies_ns:
            return 0.0
        xs = sorted(self.latencies_ns)
        idx = min(len(xs) - 1, int(round(p / 100.0 * (len(xs) - 1))))
        return xs[idx] / 1e3

    def mean_us(self) -> float:
        if not self.latencies_ns:
            return 0.0
        return sum(self.latencies_ns) / len(self.latencies_ns) / 1e3

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "ns": dict(self.ns),
                "count": dict(self.count),
                "n_latencies": len(self.latencies_ns),
            }

    def reset(self) -> None:
        with self._lock:
            self.ns.clear()
            self.count.clear()
            self.latencies_ns.clear()
            self._svc.clear()
