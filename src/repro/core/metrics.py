"""Per-category time accounting used for the paper's Fig. 6 breakdown.

Categories follow the paper's naming exactly:
  cache_metadata        — set lookup / slot alloc / state transitions
  cache_write_only      — the DRAM memcpy into a slot (hit or free slot)
  cache_eviction_and_write — a *stalled* write: evict-on-critical-path + write
  conditional_bypass    — direct BTT write because cache is full
  wbq_enqueue           — putting the slot on the write-back queue
  cache_flush           — serving PREFLUSH/FUA/fsync drains
  others                — everything else on the critical path

Read-path counters (the layered read stack of PR 2) are plain events on
``count`` — ``read_path()`` summarizes where reads were served from:
  read_hits             — transit-cache (staged write) hits
  read_tier_hits        — clean DRAM read-tier hits
  read_tier_fills       — tier populations from a backend read miss
  read_misses           — full BTT/PMem round trips
  verify_failures       — primary copies failing crc verification
  degraded_reads        — reads served from a replica instead
  verify_races          — all copies agreed, only the ledger disagreed
                          (a mid-flight write, not corruption)
  unrecoverable_reads   — no copy matched the ledger (surfaced primary)
  resync_repairs        — divergent copies rewritten by the resyncer
  tier_fill_bypassed    — read-miss fills denied by the admission layer
                          (sequential-scan bypass: the scan must not
                          flush the tier's hot set)

Commit-path counters (the transactional write pipeline of PR 3, batched
log pipeline of PR 4) live on ``count`` as well — ``commit_path()``
summarizes them:
  chain_txs             — chained-journal links logged (whole-object
                          atomicity for >span logical writes)
  group_commits         — leader-executed fsync checkpoints
  group_commit_waiters  — fsync calls that coalesced onto a leader's
                          commit instead of paying their own drain +
                          superblock pass
  log_batches           — LogBatcher flushes (one _txlock acquisition +
                          one batched slot-shard journal pass each)
  log_batch_links       — chain links written through batched passes
  log_batch_coalesced   — log()/write_multi chains that rode another
                          caller's batch instead of paying their own pass

Per-tenant counters are bumped under ``"<event>::<tenant>"`` keys and
collected with :meth:`Metrics.per_tenant` — the volume records
``wfq_vbytes::<tenant>``, the tier-aware WFQ virtual time (priced bytes)
each tenant has been charged across reads, writes and batched journal
traffic.

Service-time EWMAs (fail-slow groundwork): :meth:`Metrics.observe`
tracks a per-key exponentially weighted moving average of service
nanoseconds (plus count and max) under ``svc::<where>`` keys — the
striped volume observes ``svc::shard<i>``, the async engine
``svc::aio::<op>``, the cluster layer ``svc::node<i>``.
:meth:`Metrics.per_node` collects them, and both the volume and cluster
``scrub`` outputs surface the table: a limping shard/node (fail-slow,
not fail-stop) shows up as one EWMA drifting away from its peers long
before any heartbeat trips.

Tail-latency layer (PR 8): :meth:`observe` additionally keeps a bounded
ring of recent raw samples per key, so :meth:`digest` can report real
p50/p99 latency percentiles (an EWMA hides a bimodal limping device —
the tail is the signal).  :class:`ShardScorer` turns a digest family
(``svc::shard*`` or ``svc::node*``) into a ``healthy``/``limping``/
``dead`` state per member, a p99-based hedge delay, and a steering
penalty multiplier; ``tail_path()`` summarizes the hedged-read counters
(``hedges_fired`` must equal ``hedges_won + hedges_cancelled`` — a
hedge loser is cancelled, never abandoned).
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from contextlib import contextmanager

CATEGORIES = (
    "cache_metadata",
    "cache_write_only",
    "cache_eviction_and_write",
    "conditional_bypass",
    "wbq_enqueue",
    "cache_flush",
    "others",
)

READ_COUNTERS = (
    "read_hits",
    "read_tier_hits",
    "read_tier_fills",
    "read_misses",
    "verify_failures",
    "degraded_reads",
    "verify_races",
    "unrecoverable_reads",
    "resync_repairs",
    "tier_fill_bypassed",
)

COMMIT_COUNTERS = (
    "chain_txs",
    "group_commits",
    "group_commit_waiters",
    "log_batches",
    "log_batch_links",
    "log_batch_coalesced",
)

# Zero-copy data plane counters (PR 7) — bumped by the async engine's
# registered-buffer pool / linked-SQE machinery and by the fused transit
# kernel's callers; ``zerocopy_path()`` summarizes them:
#   copies_avoided       — submits that pinned a registered buffer (or
#                          landed a read directly in one) instead of
#                          taking a staging snapshot
#   bytes_pinned         — payload bytes that crossed the engine pinned
#   staging_copies       — defensive snapshots (unregistered mutable
#                          payloads + copy-on-evict steals)
#   staging_copy_bytes   — bytes those snapshots copied
#   links_submitted      — linked-SQE tickets (chained to a parent)
#   link_cancelled       — dependents failed with ECANCELED by a parent
#   link_depth_max       — deepest chain seen
#   fused_kernel_passes  — fused transit-kernel launches (one VMEM pass
#                          doing gather/scatter + int8 codec + checksum)
#   fused_kernel_bytes   — packed payload bytes those passes moved
#   transit_crc_errors   — restore checksums that failed verification
ZEROCOPY_COUNTERS = (
    "copies_avoided",
    "bytes_pinned",
    "staging_copies",
    "staging_copy_bytes",
    "links_submitted",
    "link_cancelled",
    "link_depth_max",
    "fused_kernel_passes",
    "fused_kernel_bytes",
    "transit_crc_errors",
)


# Tail-latency path counters (PR 8) — bumped by the hedged-read and
# slow-path-steering machinery; ``tail_path()`` summarizes them:
#   hedges_fired         — backup reads launched after the hedge delay
#   hedges_won           — hedges that completed before the primary
#   hedges_cancelled     — hedge losers cancelled (primary won first)
#   primaries_cancelled  — primary losers cancelled because the hedge won
#   hedged_reads         — reads that armed a hedge timer (fired or not)
#   steered_evictions    — eviction-pool drains deferred off a limping shard
#   steered_charges      — WFQ admissions priced up on a limping shard
#   steered_placements   — chain placements that skipped a limping node
TAIL_COUNTERS = (
    "hedges_fired",
    "hedges_won",
    "hedges_cancelled",
    "primaries_cancelled",
    "hedged_reads",
    "steered_evictions",
    "steered_charges",
    "steered_placements",
)


# Control-plane counters (PR 9) — bumped by the self-tuning loop
# (``StripedVolume.autotune_step`` / ``ClusterVolume.autotune_step``);
# per-knob move counts ride the per-tenant convention as
# ``autotune_moves::<knob>``.  ``autotune_path()`` summarizes them:
#   autotune_ticks       — control ticks observed (signal windows)
#   autotune_moves       — knob moves actually applied (hysteresis and
#                          the clamps hold most ticks at zero moves)
AUTOTUNE_COUNTERS = (
    "autotune_ticks",
    "autotune_moves",
)


# KV-paging counters (PR 10) — bumped by the serve plane's volume-backed
# spill tier (``serve.kvpager.KVPager`` + ``PagedKVCache`` host-tier
# overflow); ``kv_paging_path()`` summarizes them:
#   kv_spills             — pages written to the volume (chained write_multi)
#   kv_spill_blocks       — volume blocks those spills occupied
#   kv_dedup_hits         — spills resolved by content hash to a live slot
#                           (prefix-shared pages: refcount bump, no write)
#   kv_spill_frees        — slots freed when the last reference released
#   kv_restores           — pages read back from the volume
#   kv_prefetch_issued    — decode-ahead reads submitted before activate()
#   kv_prefetch_hits      — restores served from a completed prefetch
#   kv_prefetch_wasted    — prefetched payloads dropped unconsumed
#   kv_restore_crc_errors — wire-checksum mismatches on restore (must be 0)
KV_PAGING_COUNTERS = (
    "kv_spills",
    "kv_spill_blocks",
    "kv_dedup_hits",
    "kv_spill_frees",
    "kv_restores",
    "kv_prefetch_issued",
    "kv_prefetch_hits",
    "kv_prefetch_wasted",
    "kv_restore_crc_errors",
)


#: EWMA smoothing for :meth:`Metrics.observe` — ~the last 10-ish
#: observations dominate, so a shard/node turning slow moves its average
#: within tens of ops instead of being diluted by history
EWMA_ALPHA = 0.2

#: raw samples kept per observe() key for the percentile digests — big
#: enough for stable p99s, small enough to bound hot-path memory
SVC_RING = 512


class Metrics:
    """Thread-safe counters + nanosecond timers, cheap enough for hot paths."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.ns = defaultdict(int)        # category -> total ns
        self.count = defaultdict(int)     # category/event -> occurrences
        self.latencies_ns: list[int] = [] # per-request response times
        self.record_latencies = False
        # key -> [ewma_ns, n, max_ns] service-time summaries (observe())
        self._svc: dict[str, list] = {}
        # key -> bounded ring of recent raw samples (ns) for percentiles
        self._svc_ring: dict[str, list] = {}

    @contextmanager
    def timer(self, category: str):
        t0 = time.perf_counter_ns()
        try:
            yield
        finally:
            dt = time.perf_counter_ns() - t0
            with self._lock:
                self.ns[category] += dt
                self.count[category] += 1

    def add_ns(self, category: str, ns: int) -> None:
        with self._lock:
            self.ns[category] += ns
            self.count[category] += 1

    def bump(self, event: str, n: int = 1) -> None:
        with self._lock:
            self.count[event] += n

    def record_latency(self, ns: int) -> None:
        if self.record_latencies:
            with self._lock:
                self.latencies_ns.append(ns)

    def observe(self, key: str, ns: int) -> None:
        """Fold one service time (nanoseconds) into ``key``'s EWMA.
        Keys follow the per-tenant convention (``svc::shard3``,
        ``svc::node1``, ``svc::aio::write_multi``) so :meth:`per_node`
        can collect a whole family at once."""
        with self._lock:
            st = self._svc.get(key)
            if st is None:
                self._svc[key] = [float(ns), 1, ns]
            else:
                st[0] += EWMA_ALPHA * (ns - st[0])
                st[1] += 1
                if ns > st[2]:
                    st[2] = ns
            ring = self._svc_ring.get(key)
            if ring is None:
                self._svc_ring[key] = [ns]
            elif len(ring) < SVC_RING:
                ring.append(ns)
            else:
                # overwrite round-robin: slot by total count keeps the
                # ring a uniform window over the most recent SVC_RING
                ring[self._svc[key][1] % SVC_RING] = ns

    def per_node(self, prefix: str = "svc") -> dict[str, dict]:
        """Service-time summaries observed under ``f"{prefix}::..."``:
        suffix -> ``{"ewma_us", "n", "max_us"}``.  The fail-slow detector
        input: one EWMA drifting off its peers is a limping shard/node."""
        pre = prefix + "::"
        with self._lock:
            return {k[len(pre):]: {"ewma_us": st[0] / 1e3, "n": st[1],
                                   "max_us": st[2] / 1e3}
                    for k, st in self._svc.items() if k.startswith(pre)}

    def digest(self, prefix: str = "svc") -> dict[str, dict]:
        """Latency digests for a key family: suffix -> ``{"ewma_us",
        "n", "max_us", "p50_us", "p99_us"}``.  Percentiles come from the
        bounded raw-sample ring (an EWMA averages a bimodal limping
        device into invisibility; the p99 is the fail-slow signal)."""
        pre = prefix + "::"
        with self._lock:
            rows = {k[len(pre):]: (list(st), sorted(self._svc_ring.get(k, ())))
                    for k, st in self._svc.items() if k.startswith(pre)}
        out = {}
        for suffix, (st, xs) in rows.items():
            row = {"ewma_us": st[0] / 1e3, "n": st[1], "max_us": st[2] / 1e3}
            for name, p in (("p50_us", 50.0), ("p99_us", 99.0)):
                if xs:
                    idx = min(len(xs) - 1,
                              int(round(p / 100.0 * (len(xs) - 1))))
                    row[name] = xs[idx] / 1e3
                else:
                    row[name] = 0.0
            out[suffix] = row
        return out

    # -- report helpers -----------------------------------------------------
    def breakdown(self) -> dict[str, float]:
        """Fractional time per category (paper Fig. 6a)."""
        total = sum(self.ns[c] for c in CATEGORIES) or 1
        return {c: self.ns[c] / total for c in CATEGORIES}

    def read_path(self) -> dict[str, float]:
        """Read-path summary: every counter plus the fraction of reads
        served without touching the backend (transit or tier hit)."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in READ_COUNTERS}
        served = out["read_hits"] + out["read_tier_hits"] + out["read_misses"]
        out["dram_hit_rate"] = ((out["read_hits"] + out["read_tier_hits"])
                                / served if served else 0.0)
        return out

    def commit_path(self) -> dict[str, float]:
        """Commit-path summary: chained-tx, group-commit and batched-log
        counters plus the coalescing rates (the fraction of fsync calls
        that rode a leader's commit, and of chains that rode another
        caller's log batch)."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in COMMIT_COUNTERS}
        calls = out["group_commits"] + out["group_commit_waiters"]
        out["coalesce_rate"] = (out["group_commit_waiters"] / calls
                                if calls else 0.0)
        chains = out["log_batches"] + out["log_batch_coalesced"]
        out["log_coalesce_rate"] = (out["log_batch_coalesced"] / chains
                                    if chains else 0.0)
        return out

    def zerocopy_path(self) -> dict[str, float]:
        """Zero-copy data-plane summary: pin/snapshot/link/fused-kernel
        counters plus ``pin_rate`` — the fraction of payload-carrying
        submits that crossed the engine without a copy."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in ZEROCOPY_COUNTERS}
        moved = out["copies_avoided"] + out["staging_copies"]
        out["pin_rate"] = out["copies_avoided"] / moved if moved else 0.0
        return out

    def tail_path(self) -> dict[str, float]:
        """Tail-latency summary: hedged-read + steering counters, the
        hedge win rate, and ``hedges_unaccounted`` — every fired hedge
        must end won or cancelled (0 when losers are cleaned up, the
        acceptance invariant for the hedged read path)."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in TAIL_COUNTERS}
        out["hedge_win_rate"] = (out["hedges_won"] / out["hedges_fired"]
                                 if out["hedges_fired"] else 0.0)
        out["hedges_unaccounted"] = (out["hedges_fired"] - out["hedges_won"]
                                     - out["hedges_cancelled"])
        return out

    def autotune_path(self) -> dict:
        """Control-plane summary: tick/move counters, the moves-per-tick
        rate (a healthy controller converges: the rate decays once the
        workload steadies), and the per-knob move breakdown."""
        with self._lock:
            out: dict = {c: self.count.get(c, 0) for c in AUTOTUNE_COUNTERS}
        out["move_rate"] = (out["autotune_moves"] / out["autotune_ticks"]
                            if out["autotune_ticks"] else 0.0)
        out["per_knob"] = self.per_tenant("autotune_moves")
        return out

    def kv_paging_path(self) -> dict[str, float]:
        """KV-paging summary: spill/restore/dedup/prefetch counters plus
        ``dedup_rate`` (fraction of spill requests resolved by content
        hash without a volume write) and ``prefetch_hit_rate`` (fraction
        of volume restores served from a decode-ahead read instead of a
        synchronous wait on the activate() path)."""
        with self._lock:
            out = {c: self.count.get(c, 0) for c in KV_PAGING_COUNTERS}
        asked = out["kv_spills"] + out["kv_dedup_hits"]
        out["dedup_rate"] = out["kv_dedup_hits"] / asked if asked else 0.0
        out["prefetch_hit_rate"] = (out["kv_prefetch_hits"]
                                    / out["kv_restores"]
                                    if out["kv_restores"] else 0.0)
        return out

    def per_tenant(self, prefix: str) -> dict[str, int]:
        """Collect per-tenant counters bumped as ``f"{prefix}::{t}"``
        (e.g. ``per_tenant('wfq_vbytes')`` -> tenant -> priced bytes)."""
        pre = prefix + "::"
        with self._lock:
            return {k[len(pre):]: v for k, v in self.count.items()
                    if k.startswith(pre)}

    def percentile_us(self, p: float) -> float:
        if not self.latencies_ns:
            return 0.0
        xs = sorted(self.latencies_ns)
        idx = min(len(xs) - 1, int(round(p / 100.0 * (len(xs) - 1))))
        return xs[idx] / 1e3

    def mean_us(self) -> float:
        if not self.latencies_ns:
            return 0.0
        return sum(self.latencies_ns) / len(self.latencies_ns) / 1e3

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "ns": dict(self.ns),
                "count": dict(self.count),
                "n_latencies": len(self.latencies_ns),
            }

    def reset(self) -> None:
        with self._lock:
            self.ns.clear()
            self.count.clear()
            self.latencies_ns.clear()
            self._svc.clear()
            self._svc_ring.clear()


class ShardScorer:
    """Fail-slow detector over one :meth:`Metrics.digest` family.

    Classifies every member of a service-time key family
    (``svc::shard*`` / ``svc::node*``) against its PEERS — the fail-slow
    literature's "limplock" signature is one device drifting 10–100x off
    the cohort while still completing everything, so absolute thresholds
    lose the moment the workload shifts but a peer-relative ratio does
    not:

      ``healthy``   p99 < ``limping_ratio`` x the peer-median p50
      ``limping``   p99 >= that bar but below ``dead_ratio`` x
      ``dead``      p99 >= ``dead_ratio`` x the peer-median p50, or the
                    member was explicitly marked (heartbeat integration)

    The scorer also derives the two control outputs the data plane
    steers by: :meth:`hedge_delay_us` — the healthy-cohort p99, the
    classic hedged-request trigger (fire the backup only after the
    request has outlived what a healthy replica would take) — and
    :meth:`penalty` — a charge/placement multiplier (1.0 healthy,
    ``limping_penalty`` limping, ``dead_penalty`` dead) consumed by the
    WFQ pricing, the eviction pool and the placement policy.
    """

    def __init__(self, metrics: "Metrics", family: str = "shard", *,
                 prefix: str = "svc", limping_ratio: float = 4.0,
                 dead_ratio: float = 200.0, min_samples: int = 8,
                 limping_penalty: float = 4.0,
                 dead_penalty: float = 64.0) -> None:
        self.metrics = metrics
        self.family = family
        self.prefix = prefix
        self.limping_ratio = limping_ratio
        self.dead_ratio = dead_ratio
        self.min_samples = min_samples
        self.limping_penalty = limping_penalty
        self.dead_penalty = dead_penalty
        self._marked_dead: set[str] = set()

    def _rows(self) -> dict[str, dict]:
        dig = self.metrics.digest(self.prefix)
        return {k: v for k, v in dig.items() if k.startswith(self.family)}

    def mark_dead(self, member: str) -> None:
        """Heartbeat/fail-stop override: force ``member`` to ``dead``."""
        self._marked_dead.add(member)

    def clear_dead(self, member: str) -> None:
        self._marked_dead.discard(member)

    def table(self) -> dict[str, dict]:
        """Digest rows + a ``state`` per member (the scrub surface)."""
        rows = self._rows()
        ref = self._peer_median_p50(rows)
        out = {}
        for k, row in sorted(rows.items()):
            row = dict(row)
            row["state"] = self._state(k, row, ref)
            out[k] = row
        return out

    def states(self) -> dict[str, str]:
        return {k: row["state"] for k, row in self.table().items()}

    def limping(self) -> set[str]:
        """Members to steer around (limping OR dead)."""
        return {k for k, s in self.states().items() if s != "healthy"}

    def penalty(self, member: str) -> float:
        state = self.states().get(member, "healthy")
        if state == "dead":
            return self.dead_penalty
        if state == "limping":
            return self.limping_penalty
        return 1.0

    def hedge_delay_us(self, default_us: float = 0.0) -> float:
        """p99 of the healthy cohort — hedge a replicated read only once
        it has outlived what a healthy member would take."""
        rows = self._rows()
        ref = self._peer_median_p50(rows)
        healthy = sorted(row["p99_us"] for k, row in rows.items()
                        if self._state(k, row, ref) == "healthy"
                        and row["n"] >= self.min_samples)
        if not healthy:
            return default_us
        return healthy[len(healthy) // 2]

    def _peer_median_p50(self, rows: dict[str, dict]) -> float:
        xs = sorted(row["p50_us"] for row in rows.values()
                    if row["n"] >= self.min_samples and row["p50_us"] > 0)
        if not xs:
            return 0.0
        # LOWER median: with an even cohort (2 replicas is the common
        # case) the upper median would let a slow member become its own
        # reference and classify itself healthy
        return xs[(len(xs) - 1) // 2]

    def _state(self, member: str, row: dict, ref: float) -> str:
        if member in self._marked_dead:
            return "dead"
        if ref <= 0 or row["n"] < self.min_samples:
            return "healthy"          # not enough evidence to steer yet
        ratio = row["p99_us"] / ref
        if ratio >= self.dead_ratio:
            return "dead"
        if ratio >= self.limping_ratio:
            return "limping"
        return "healthy"
