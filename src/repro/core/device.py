"""Block-device front end: composes a caching policy with a BTT backend and
exposes the bio interface the storage stack (benchmarks, ckpt engine) uses.

Device variants (paper §5 Setup):
  btt       — BTT alone (CoW+Flog atomicity, no cache)
  raw       — raw PMem, in-place writes, NO atomicity        (paper "PMem")
  dax       — raw PMem minus the block-layer bookkeeping     (paper "DAX")
  caiti     — BTT + Caiti transit cache                       (the paper)
  caiti-noee / caiti-nobp — ablations ('w/o EE', 'w/o BP')
  pmbd / pmbd70 / lru / coactive — staging baselines
"""
from __future__ import annotations

import os
import time

import numpy as np

from .bio import Bio, BioFlags, BioOp, SUCCESS
from .btt import BTT
from .cache import CaitiCache, CaitiConfig
from .metrics import Metrics
from .pmem import PMemSpace, LatencyModel, NO_LATENCY
from .policies import CoActiveCache, LRUCache, PMBD70Cache, PMBDCache

POLICIES = ("btt", "raw", "dax", "caiti", "caiti-noee", "caiti-nobp",
            "pmbd", "pmbd70", "lru", "coactive")


class _RawPMemDev:
    """In-place writes to PMem — fast, but a torn write is visible (no CoW)."""

    def __init__(self, pmem: PMemSpace, n_lbas: int, dax: bool = False,
                 metrics: Metrics | None = None) -> None:
        self.pmem = pmem
        self.n_lbas = n_lbas
        self.metrics = metrics or Metrics()
        # the block layer's per-bio software overhead that DAX avoids;
        # calibrated from the paper's BTT-vs-DAX gap discussion (§3)
        self._soft_ns = 0 if dax else 400

    def write(self, lba: int, data) -> int:
        t0 = time.perf_counter_ns()
        if self._soft_ns:
            end = t0 + self._soft_ns
            while time.perf_counter_ns() < end:
                pass
        self.pmem.write_block(lba, np.frombuffer(data, dtype=np.uint8))
        self.metrics.record_latency(time.perf_counter_ns() - t0)
        return SUCCESS

    def read(self, lba: int, out=None) -> np.ndarray:
        return self.pmem.read_block(lba, out=out)

    def flush(self, fua: bool = False) -> int:
        self.pmem.persist()
        return SUCCESS

    def fsync(self) -> int:
        return self.flush(fua=True)

    def occupancy(self) -> float:
        return 0.0

    def close(self) -> None:
        pass


class _BTTDev:
    """BTT without any cache (the paper's 'BTT' baseline)."""

    def __init__(self, btt: BTT, metrics: Metrics | None = None) -> None:
        self.btt = btt
        self.metrics = metrics or Metrics()

    def write(self, lba: int, data) -> int:
        t0 = time.perf_counter_ns()
        self.btt.write(lba, data)
        self.metrics.record_latency(time.perf_counter_ns() - t0)
        return SUCCESS

    def read(self, lba: int, out=None) -> np.ndarray:
        return self.btt.read(lba, out=out)

    def flush(self, fua: bool = False) -> int:
        self.btt.flush()
        return SUCCESS

    def fsync(self) -> int:
        return self.flush(fua=True)

    def occupancy(self) -> float:
        return 0.0

    def close(self) -> None:
        pass


class BlockDevice:
    """bio-speaking device: policy cache (or none) over BTT over PMem."""

    def __init__(self, impl, metrics: Metrics) -> None:
        self.impl = impl
        self.metrics = metrics

    # -- bio interface -------------------------------------------------------
    def submit_bio(self, bio: Bio) -> int:
        if bio.flags & BioFlags.REQ_PREFLUSH:
            self.impl.flush(fua=bool(bio.flags & BioFlags.REQ_FUA))
        if bio.op is BioOp.WRITE:
            ret = self.impl.write(bio.lba, bio.data)
        elif bio.op is BioOp.READ:
            self.impl.read(bio.lba)
            ret = SUCCESS
        else:
            ret = SUCCESS
        if bio.flags & BioFlags.REQ_FUA and bio.op is BioOp.WRITE:
            self.impl.flush(fua=True)
        bio.complete(ret)
        return ret

    # -- direct convenience API ----------------------------------------------
    def write(self, lba: int, data) -> int:
        return self.impl.write(lba, data)

    def read(self, lba: int, out=None) -> np.ndarray:
        return self.impl.read(lba, out=out)

    def flush(self) -> int:
        return self.impl.flush(fua=False)

    def fsync(self) -> int:
        return self.impl.fsync()

    def occupancy(self) -> float:
        return self.impl.occupancy()

    def close(self) -> None:
        self.impl.close()


def make_device(policy: str, *, n_lbas: int, block_size: int = 4096,
                cache_bytes: int = 512 << 20, backend: str = "ram",
                path: str | None = None,
                latency: LatencyModel | None = None,
                n_workers: int = 4, nfree: int | None = None,
                record_latencies: bool = False,
                evict_pool=None, read_tier=None, read_tier_bytes: int = 0,
                tier_ns: int = 0) -> BlockDevice:
    """Build a complete device stack for the given policy name.

    A file-backed pool that already carries a BTT info block is RECOVERED
    (Flog replay), not re-formatted — reopening after a crash must land on
    the last committed state.

    ``evict_pool`` (caiti policies only) hands background eviction to a
    shared cross-device pool (see ``repro.volume.SharedEvictionPool``)
    instead of private worker threads.  ``read_tier`` attaches an existing
    clean DRAM read tier (``repro.volume.ReadTier``, shared across volume
    shards via ``tier_ns``); ``read_tier_bytes > 0`` builds a private one
    for this device instead.  Caiti policies only — the staging baselines
    keep the paper's read path untouched.
    """
    assert policy in POLICIES, f"unknown policy {policy!r}"
    latency = NO_LATENCY if latency is None else latency
    metrics = Metrics()
    metrics.record_latencies = record_latencies
    # BTT needs headroom for metadata + free blocks
    meta_blocks = 2 + (n_lbas * 8) // block_size + 64
    existing = backend == "file" and path is not None and \
        os.path.exists(path) and os.path.getsize(path) > 0
    pmem = PMemSpace(n_lbas + 256 + meta_blocks, block_size=block_size,
                     backend=backend, path=path, latency=latency)
    if policy in ("raw", "dax"):
        impl = _RawPMemDev(pmem, n_lbas, dax=(policy == "dax"), metrics=metrics)
        return BlockDevice(impl, metrics)
    from .btt import _INFO_MAGIC
    fresh = not (existing and pmem.load_u64(0) == _INFO_MAGIC)
    btt = BTT(pmem, n_lbas=n_lbas, nfree=nfree, fresh=fresh)
    if policy == "btt":
        impl = _BTTDev(btt, metrics=metrics)
    elif policy.startswith("caiti"):
        cfg = CaitiConfig(capacity_bytes=cache_bytes, block_size=block_size,
                          n_workers=n_workers,
                          eager_eviction=(policy != "caiti-noee"),
                          conditional_bypass=(policy != "caiti-nobp"))
        if read_tier is None and read_tier_bytes > 0:
            from repro.volume.read_tier import ReadTier
            read_tier = ReadTier(read_tier_bytes, block_size)
        impl = CaitiCache(btt, cfg, metrics=metrics, evict_pool=evict_pool,
                          read_tier=read_tier, tier_ns=tier_ns)
    elif policy == "pmbd":
        impl = PMBDCache(btt, cache_bytes, metrics=metrics)
    elif policy == "pmbd70":
        impl = PMBD70Cache(btt, cache_bytes, metrics=metrics)
    elif policy == "lru":
        impl = LRUCache(btt, cache_bytes, metrics=metrics)
    elif policy == "coactive":
        impl = CoActiveCache(btt, cache_bytes, metrics=metrics)
    else:  # pragma: no cover
        raise ValueError(policy)
    return BlockDevice(impl, metrics)
