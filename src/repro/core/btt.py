"""Block Translation Table — faithful re-implementation of the kernel driver.

Semantics reproduced from the paper (Section 2.2, Figure 1) and the kernel
documentation it cites:

* The PMem space is split into *arenas*; each arena holds two redundant Info
  blocks, a *map* (lba -> pba), a *Flog* (per-lane redo log, two alternating
  slots per lane), and data blocks.
* ``nfree`` lanes (min(n_cores, 256)); each lane owns one free block.
* A write is CoW: (1) take the lane, (2) write payload into the lane's free
  block, (3) append a Flog entry (lba, old_pba, new_pba, seq), (4) commit by
  the 8-byte atomic map update, (5) the old pba becomes the lane's free block.
* Crash recovery replays the Flog: an entry whose map slot does not equal its
  ``new_pba`` denotes an uncommitted write — the lba still maps to the old,
  complete block; the (possibly torn) free block is simply reused.  This is
  the block-level write atomicity that Caiti must not break.

All BTT metadata lives *inside* the PMemSpace so that file-backed pools give
real crash recovery across process restarts.
"""
from __future__ import annotations

import os
import threading

import numpy as np

from .pmem import PMemSpace

_INFO_MAGIC = 0xB77B77B7
_FLOG_SLOTS = 2          # alternating flog pairs, as in kernel BTT
_FLOG_ENTRY_U64 = 4      # lba, old_pba, new_pba, seq


class BTT:
    """One-arena BTT device on top of a PMemSpace.

    Layout (in blocks):  [info | map | flog | data ...]
    ``n_lbas`` external blocks are served from ``n_lbas + nfree`` data blocks.
    """

    def __init__(self, pmem: PMemSpace, n_lbas: int, nfree: int | None = None,
                 fresh: bool = True) -> None:
        self.pmem = pmem
        self.block_size = pmem.block_size
        self.n_lbas = int(n_lbas)
        self.nfree = int(nfree or min(os.cpu_count() or 8, 256))
        if not fresh:
            # geometry is authoritative from the pool's info block
            assert pmem.load_u64(0) == _INFO_MAGIC, "not a BTT pool"
            self.n_lbas = pmem.load_u64(8)
            self.nfree = pmem.load_u64(16)
        self._compute_layout()
        self._init_runtime()
        self.recovery_stats: dict = {}
        if fresh:
            self._format()
        else:
            self.recovery_stats = self.recover()

    def _compute_layout(self) -> None:
        map_bytes = self.n_lbas * 8
        flog_bytes = self.nfree * _FLOG_SLOTS * _FLOG_ENTRY_U64 * 8
        bs = self.block_size
        self._map_off = bs                                   # after info block
        self._flog_off = self._map_off + ((map_bytes + bs - 1) // bs) * bs
        data_off = self._flog_off + ((flog_bytes + bs - 1) // bs) * bs
        self._data_base = data_off // bs                      # first data pba
        need = self._data_base + self.n_lbas + self.nfree
        assert need <= self.pmem.n_blocks, (
            f"PMem too small: need {need} blocks, have {self.pmem.n_blocks}")

    def _init_runtime(self) -> None:
        self._nstripes = 1024
        self._stripes = [threading.Lock() for _ in range(self._nstripes)]
        self._lane_locks = [threading.Lock() for _ in range(self.nfree)]
        self._lane_free = [0] * self.nfree   # internal pba per lane
        self._lane_seq = [0] * self.nfree    # flog sequence per lane
        self._lane_rr = 0
        self.writes = 0
        self.reads = 0

    # ------------------------------------------------------------- metadata
    def _map_cell(self, lba: int) -> int:
        return self._map_off + lba * 8

    def _flog_cell(self, lane: int, slot: int, field: int) -> int:
        return (self._flog_off
                + ((lane * _FLOG_SLOTS + slot) * _FLOG_ENTRY_U64 + field) * 8)

    def _format(self) -> None:
        p = self.pmem
        p.store_u64(0, _INFO_MAGIC)
        p.store_u64(8, self.n_lbas)
        p.store_u64(16, self.nfree)
        # identity map: lba i -> internal block i
        for lba in range(self.n_lbas):
            p.store_u64(self._map_cell(lba), lba)
        # free blocks are the tail blocks; seed flog as the kernel does:
        # lba=0, old=new=free, seq=1.  On recovery map[0] != new, so the
        # lane's free block is re-derived as ``new`` — correct and benign.
        for lane in range(self.nfree):
            free = self.n_lbas + lane
            self._lane_free[lane] = free
            self._lane_seq[lane] = 1
            self._write_flog(lane, slot=1 % _FLOG_SLOTS, lba=0,
                             old=free, new=free, seq=1)
        p.persist()

    def _load_map(self, lba: int) -> int:
        return self.pmem.load_u64(self._map_cell(lba))

    def _store_map(self, lba: int, pba: int) -> None:
        # THE commit point: one 8-byte atomic store (kernel BTT does the same).
        self.pmem.store_u64(self._map_cell(lba), pba)

    def _write_flog(self, lane: int, slot: int, lba: int, old: int, new: int,
                    seq: int) -> None:
        p = self.pmem
        p.store_u64(self._flog_cell(lane, slot, 0), lba)
        p.store_u64(self._flog_cell(lane, slot, 1), old)
        p.store_u64(self._flog_cell(lane, slot, 2), new)
        # seq written last — it validates the entry
        p.store_u64(self._flog_cell(lane, slot, 3), seq)

    def _read_flog(self, lane: int, slot: int) -> tuple[int, int, int, int]:
        p = self.pmem
        return tuple(p.load_u64(self._flog_cell(lane, slot, f))  # type: ignore
                     for f in range(4))

    # ---------------------------------------------------------------- I/O
    def pick_lane(self) -> int:
        """Kernel BTT uses the CPU id; we round-robin across lanes."""
        self._lane_rr = (self._lane_rr + 1) % self.nfree
        return self._lane_rr

    def write(self, lba: int, data, lane: int | None = None) -> None:
        """Atomic block write via CoW + Flog (paper Fig. 1 steps 1-4)."""
        assert 0 <= lba < self.n_lbas
        if lane is None:
            lane = self.pick_lane()
        lane_lock = self._lane_locks[lane % self.nfree]
        stripe = self._stripes[lba % self._nstripes]
        with lane_lock:
            lane = lane % self.nfree
            free = self._lane_free[lane]
            # (2) CoW: payload goes to the lane's free block first
            self.pmem.write_block(self._data_base + free, data)
            with stripe:
                old = self._load_map(lba)
                seq = self._lane_seq[lane] + 1
                # (3) redo log the mapping change
                self._write_flog(lane, slot=seq % _FLOG_SLOTS, lba=lba,
                                 old=old, new=free, seq=seq)
                # (4) commit: 8-byte atomic map update
                self._store_map(lba, free)
                self._lane_seq[lane] = seq
            # (5) the swapped-out block replenishes the lane
            self._lane_free[lane] = old
        self.writes += 1

    def read(self, lba: int, out: np.ndarray | None = None) -> np.ndarray:
        assert 0 <= lba < self.n_lbas
        stripe = self._stripes[lba % self._nstripes]
        with stripe:
            pba = self._load_map(lba)
            buf = self.pmem.read_block(self._data_base + pba, out=out)
        self.reads += 1
        return buf

    def flush(self) -> None:
        """BTT has no volatile state for data; persist the pool (msync)."""
        self.pmem.persist()

    # ------------------------------------------------------------- recovery
    def recover(self) -> dict:
        """Replay the Flog after a crash (kernel ``btt_freelist_init`` logic).

        A valid flog entry is only written *after* its data block is fully
        persisted, so recovery **rolls forward**: if the map still shows
        ``old_pba`` the 8-byte commit was lost and we redo it.  If the map
        shows anything else (``new_pba`` already, or an even newer pba from
        another lane's later write to the same lba) we leave it alone.  The
        lane's free block is always the entry's ``old_pba``.
        """
        p = self.pmem
        assert p.load_u64(0) == _INFO_MAGIC, "not a BTT pool"
        if (p.load_u64(8), p.load_u64(16)) != (self.n_lbas, self.nfree):
            # pool geometry differs from the constructor's guess: re-derive
            self.n_lbas = p.load_u64(8)
            self.nfree = p.load_u64(16)
            self._compute_layout()
            self._init_runtime()
        redone = 0
        clean = 0
        for lane in range(self.nfree):
            entries = [self._read_flog(lane, s) for s in range(_FLOG_SLOTS)]
            # newest valid entry wins (seq written last validates an entry;
            # a torn entry keeps its stale, lower seq and loses here)
            lba, old, new, seq = max(entries, key=lambda e: e[3])
            self._lane_seq[lane] = seq
            self._lane_free[lane] = old if old != new else new
            if old == new:
                clean += 1          # freshly formatted / untouched lane
                continue
            cur = self._load_map(lba)
            if cur == old:
                # commit was lost mid-flight: data is complete (flog entry is
                # valid ⇒ payload persisted first) — roll the map forward.
                self._store_map(lba, new)
                redone += 1
            else:
                clean += 1
        p.persist()
        return {"redone_lanes": redone, "clean_lanes": clean}
