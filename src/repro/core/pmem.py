"""PMem space emulation: a block-addressed persistent byte pool.

Two backends:
  * ``ram``  — a numpy byte buffer (fast; used by tests & latency studies).
  * ``file`` — an mmap'd file (used by the checkpoint engine so data really
               persists across process crashes).

Latency model
-------------
The container has no Optane DIMMs, so we inject the *relative* costs the paper
relies on. Numbers follow the paper's cited measurement study (Yang et al.,
FAST'20 [82]): PMem sequential write bandwidth is roughly 1/3 of DRAM, read
roughly 1/2–1/3, and the device's internal access granularity is 256 B.  The
emulation adds a busy-wait on top of the real memcpy so that *concurrency
behaviour is real* (GIL is released during numpy copies; background eviction
genuinely overlaps) while *ratios are faithful*.  All rates are configurable;
benchmarks state the model next to every result.
"""
from __future__ import annotations

import mmap
import os
import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LatencyModel:
    """Per-medium bandwidth/latency injection. Set a bandwidth to 0 to disable."""

    pmem_write_gbps: float = 2.0    # Optane AppDirect ~2.3 GB/s/DIMM streaming write
    pmem_read_gbps: float = 6.0     # ~6.6 GB/s/DIMM read
    dram_gbps: float = 0.0          # real memcpy only (DRAM is the fast tier)
    pmem_write_fixed_ns: int = 300  # media write latency floor (ns)
    pmem_read_fixed_ns: int = 170   # load latency floor (ns)
    access_granularity: int = 256   # Optane internal block (write amplification)

    def write_delay_ns(self, nbytes: int) -> int:
        if self.pmem_write_gbps <= 0:
            return 0
        # round up to the 256B access granularity (write amplification)
        g = self.access_granularity
        eff = ((nbytes + g - 1) // g) * g
        return self.pmem_write_fixed_ns + int(eff / self.pmem_write_gbps)

    def read_delay_ns(self, nbytes: int) -> int:
        if self.pmem_read_gbps <= 0:
            return 0
        g = self.access_granularity
        eff = ((nbytes + g - 1) // g) * g
        return self.pmem_read_fixed_ns + int(eff / self.pmem_read_gbps)


NO_LATENCY = LatencyModel(pmem_write_gbps=0.0, pmem_read_gbps=0.0,
                          pmem_write_fixed_ns=0, pmem_read_fixed_ns=0)


def _busy_wait_ns(ns: int) -> None:
    if ns <= 0:
        return
    end = time.perf_counter_ns() + ns
    while time.perf_counter_ns() < end:
        pass


class PMemSpace:
    """A persistent pool of ``n_blocks`` blocks of ``block_size`` bytes.

    Crash injection: ``crash_hook`` (if set) is invoked with a label before and
    mid-way through every store; raising ``SimulatedCrash`` there models a
    power failure, potentially leaving a *torn block* — which is exactly what
    BTT's CoW must tolerate.
    """

    def __init__(self, n_blocks: int, block_size: int = 4096,
                 backend: str = "ram", path: str | None = None,
                 latency: LatencyModel = NO_LATENCY) -> None:
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.latency = latency
        self.backend = backend
        self.path = path
        self.crash_hook = None  # callable(label: str) -> None
        nbytes = self.n_blocks * self.block_size
        if backend == "ram":
            self._buf = np.zeros(nbytes, dtype=np.uint8)
            self._mm = None
        elif backend == "file":
            assert path is not None
            self._fd = os.open(path, os.O_RDWR | os.O_CREAT, 0o644)
            os.ftruncate(self._fd, nbytes)
            self._mm = mmap.mmap(self._fd, nbytes)
            self._buf = np.frombuffer(self._mm, dtype=np.uint8)
        else:
            raise ValueError(f"unknown backend {backend}")

    # ------------------------------------------------------------------ I/O
    def write_block(self, pba: int, data) -> None:
        """Store one block. Honors the latency model and crash hook."""
        assert 0 <= pba < self.n_blocks, f"pba {pba} out of range"
        src = np.frombuffer(data, dtype=np.uint8)
        assert src.nbytes <= self.block_size
        off = pba * self.block_size
        if self.crash_hook is not None:
            self.crash_hook("pmem_write_begin")
            # model a torn write: copy only the first half, then crash check
            half = src.nbytes // 2
            self._buf[off:off + half] = src[:half]
            self.crash_hook("pmem_write_mid")
            self._buf[off + half:off + src.nbytes] = src[half:]
        else:
            self._buf[off:off + src.nbytes] = src
        _busy_wait_ns(self.latency.write_delay_ns(src.nbytes))

    def read_block(self, pba: int, out: np.ndarray | None = None) -> np.ndarray:
        assert 0 <= pba < self.n_blocks
        off = pba * self.block_size
        view = self._buf[off:off + self.block_size]
        _busy_wait_ns(self.latency.read_delay_ns(self.block_size))
        if out is not None:
            out[:] = view
            return out
        return view.copy()

    # Raw 8-byte atomic-ish cell access used for BTT map & flog sequence words.
    # A single np.uint64 store is atomic w.r.t. Python threads (GIL) which
    # mirrors the 8-byte atomic store BTT relies on as its commit point.
    def store_u64(self, byte_off: int, value: int) -> None:
        if self.crash_hook is not None:
            self.crash_hook("pmem_u64_store")
        self._buf[byte_off:byte_off + 8] = np.frombuffer(
            np.uint64(value).tobytes(), dtype=np.uint8)

    def load_u64(self, byte_off: int) -> int:
        return int(np.frombuffer(self._buf[byte_off:byte_off + 8].tobytes(),
                                 dtype=np.uint64)[0])

    def persist(self) -> None:
        """msync for the file backend (fsync of the pool)."""
        if self._mm is not None:
            self._mm.flush()

    def close(self) -> None:
        if self._mm is not None:
            self._mm.flush()
            self._buf = None          # release the exported buffer first
            self._mm.close()
            os.close(self._fd)
            self._mm = None


class SimulatedCrash(Exception):
    """Raised by crash hooks to model power failure at a chosen point."""
