"""Block-I/O request model mirroring the Linux bio interface BTT exposes.

The paper's device speaks standard ``bio`` with flags; Caiti must support all
of them (Section 4.4).  We reproduce the subset that carries semantics for the
caching layer: REQ_PREFLUSH (flush the volatile device cache before the
request), REQ_FUA (force unit access — ack only after durable commit) and
SYNC (the submitter synchronously waits).  An ``fsync`` is translated, exactly
as in the kernel, to an empty bio with PREFLUSH|FUA set.
"""
from __future__ import annotations

import enum
import itertools
import threading
from dataclasses import dataclass, field


class BioFlags(enum.IntFlag):
    NONE = 0
    REQ_PREFLUSH = 1 << 0   # flush device cache before servicing this bio
    REQ_FUA = 1 << 1        # ack only once data is durable in the backend
    SYNC = 1 << 2           # submitter waits synchronously


class BioOp(enum.Enum):
    READ = "read"
    WRITE = "write"
    FLUSH = "flush"         # empty bio carrying PREFLUSH (ext4 journal tick)


#: Result codes, matching the paper's SUCCESS / -EIO convention.
SUCCESS = 0
EIO = -5

_bio_ids = itertools.count()


@dataclass
class Bio:
    """One block I/O request (one ``lba``, one block of data)."""

    op: BioOp
    lba: int = -1
    data: bytes | memoryview | None = None
    flags: BioFlags = BioFlags.NONE
    bio_id: int = field(default_factory=lambda: next(_bio_ids))
    # Completion signalling (device sets result then fires the event).
    result: int | None = None
    _done: threading.Event = field(default_factory=threading.Event, repr=False)

    def complete(self, result: int) -> None:
        self.result = result
        self._done.set()

    def wait(self, timeout: float | None = None) -> int:
        if not self._done.wait(timeout):
            raise TimeoutError(f"bio {self.bio_id} did not complete")
        assert self.result is not None
        return self.result


def fsync_bio() -> Bio:
    """An fsync as the kernel would emit it: empty PREFLUSH|FUA bio."""
    return Bio(op=BioOp.FLUSH, flags=BioFlags.REQ_PREFLUSH | BioFlags.REQ_FUA | BioFlags.SYNC)


def preflush_bio() -> Bio:
    """The ext4 5-second journal-commit flush: PREFLUSH, *not* SYNC."""
    return Bio(op=BioOp.FLUSH, flags=BioFlags.REQ_PREFLUSH)
