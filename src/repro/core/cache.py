"""Caiti — caching with I/O transit (the paper's core contribution, §4).

Structure (Figure 4):
  * one contiguous DRAM buffer partitioned into uniform *slots*;
  * slot headers carry {slot number, lba, state, WBQ link, lock};
  * logical *cache sets* indexed by ``hash(lba)`` — no mapping table;
  * a single *global free set* (CAS-style pop/push) feeding all sets;
  * slot states: Free → Pending → Valid → Evicting → Free.

Write policies (§4.3.1, Algorithm 1):
  * **eager eviction** — the instant a slot turns Valid it is enqueued on its
    set's write-back queue (WBQ) and a background pool thread transits it to
    the PMem-based block device (BTT);
  * **conditional bypass** — a write miss against a full cache goes straight
    to BTT (one PMem write beats evict-then-fill = PMem write + DRAM write).

Reading policy (§4.3.2): serve Valid/Evicting hits from DRAM, redirect misses
to BTT, never allocate on read miss (writes are prioritized).  An optional
``read_tier`` (``repro.volume.ReadTier``) layers a *clean* DRAM read cache
under the transit cache: probed after a transit miss, filled from the BTT
read (fenced against racing writes), re-populated by eviction writebacks,
and invalidated by every write before it stages — the transit cache keeps
the write path exactly as the paper specifies, the tier only shortens the
read-miss path.

Locking discipline (deadlock-free order): a foreground thread takes
``set.lock`` only for table/WBQ surgery and *releases it before* taking
``slot.lock``; it re-validates ``slot.lba``/state after acquiring and retries
if the slot was recycled underneath it.  The evictor holds ``slot.lock``
across the BTT write (so a racing write/read to the same lba waits for the
persist to finish — the paper's rule for the Evicting state) and takes
``set.lock`` only after, for removal.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from dataclasses import dataclass

import numpy as np

from .btt import BTT
from .metrics import Metrics

# Slot states (paper §4.2)
FREE, PENDING, VALID, EVICTING = range(4)
_STATE_NAMES = ("Free", "Pending", "Valid", "Evicting")


class SlotHeader:
    __slots__ = ("idx", "lba", "state", "lock", "set_idx", "queued")

    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.lba = -1
        self.state = FREE
        self.lock = threading.Lock()
        self.set_idx = -1
        self.queued = False


class CacheSet:
    __slots__ = ("lock", "table", "wbq")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.table: dict[int, SlotHeader] = {}   # lba -> slot
        self.wbq: deque[SlotHeader] = deque()    # write-back queue


def _hash_lba(lba: int) -> int:
    """Cheap mixer so striding writes still spread across sets."""
    x = (lba * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    return x >> 17


@dataclass
class CaitiConfig:
    capacity_bytes: int = 512 << 20
    block_size: int = 4096
    n_sets: int = 256
    n_workers: int = 4            # background eviction pool
    eager_eviction: bool = True   # 'w/o EE' ablation when False
    conditional_bypass: bool = True  # 'w/o BP' ablation when False

    @property
    def n_slots(self) -> int:
        return max(1, self.capacity_bytes // self.block_size)


class CaitiCache:
    """The I/O transit cache in front of a BTT block device.

    ``evict_pool`` (optional) hands background write-back to a shared
    multi-device pool (``repro.volume.SharedEvictionPool``) instead of
    per-device worker threads — the volume manager drains all shards from
    one set of eviction cores.  ``bypass_hook`` (optional) extends the
    paper's conditional bypass with a *global* condition: when the hook
    returns True a write miss transits straight to BTT even though this
    shard still has free slots (the volume's aggregate-staged watermark).
    ``read_tier`` (optional, possibly shared across shards) serves read
    misses from clean DRAM slots; ``tier_ns`` namespaces this device's
    lbas inside a shared tier (the volume passes its shard index).
    """

    def __init__(self, btt: BTT, cfg: CaitiConfig | None = None,
                 metrics: Metrics | None = None, evict_pool=None,
                 bypass_hook=None, read_tier=None, tier_ns: int = 0,
                 admission=None) -> None:
        self.btt = btt
        self.cfg = cfg or CaitiConfig(block_size=btt.block_size)
        assert self.cfg.block_size == btt.block_size
        self.metrics = metrics or Metrics()
        self.bypass_hook = bypass_hook
        # unified admission layer (repro.volume.AdmissionPolicy): scan
        # detection decides read-tier fills; the volume also routes its
        # aggregate bypass watermark through it (via bypass_hook)
        self.admission = admission
        self.read_tier = read_tier
        self.tier_ns = tier_ns
        n = self.cfg.n_slots
        self._buf = np.zeros((n, self.cfg.block_size), dtype=np.uint8)
        self._slots = [SlotHeader(i) for i in range(n)]
        self._sets = [CacheSet() for _ in range(self.cfg.n_sets)]
        # global free set — deque.pop/append are atomic under the GIL, the
        # analogue of the paper's CAS alloc/dealloc
        self._free: deque[SlotHeader] = deque(self._slots)
        # flush accounting: flush waits until everything enqueued before it
        # has been written back
        self._evict_lock = threading.Lock()
        self._evict_cond = threading.Condition(self._evict_lock)
        self._enqueued = 0
        self._completed = 0
        # one-shot drain waiters: (target_enqueued, callback) fired from
        # the eviction completion path once everything enqueued at
        # registration time has been written back — the async frontend
        # completes flush tickets here instead of parking a thread in
        # flush()
        self._drain_waiters: list[tuple[int, object]] = []
        # background pool: private threads, or a shared cross-shard pool
        self._pool = evict_pool
        self._work: queue.SimpleQueue[SlotHeader | None] = queue.SimpleQueue()
        self._stop = False
        if evict_pool is not None:
            evict_pool.register(self)
            self._workers = []
        else:
            self._workers = [
                threading.Thread(target=self._evict_worker, daemon=True,
                                 name=f"caiti-evict-{i}")
                for i in range(self.cfg.n_workers)
            ]
            for w in self._workers:
                w.start()

    # ----------------------------------------------------------- internals
    def _set_for(self, lba: int) -> CacheSet:
        return self._sets[_hash_lba(lba) % self.cfg.n_sets]

    def cache_full(self) -> bool:
        return not self._free

    def _alloc_slot(self) -> SlotHeader | None:
        try:
            return self._free.pop()      # CAS-style pop
        except IndexError:
            return None

    def _notify_eviction(self, sh: SlotHeader) -> None:
        with self._evict_lock:
            self._enqueued += 1
        if self._pool is not None:
            self._pool.submit(self, sh)
        else:
            self._work.put(sh)

    def staged_slots(self) -> int:
        """Slots currently occupied (Pending/Valid/Evicting)."""
        return len(self._slots) - len(self._free)

    def _complete_eviction(self, n: int = 1) -> None:
        with self._evict_cond:
            self._completed += n
            self._evict_cond.notify_all()
            ready = [cb for tgt, cb in self._drain_waiters
                     if self._completed >= tgt]
            if ready:
                self._drain_waiters = [
                    (tgt, cb) for tgt, cb in self._drain_waiters
                    if self._completed < tgt]
        for cb in ready:             # outside the lock: callbacks may
            cb()                     # re-enter the cache/engine

    def add_drain_waiter(self, cb) -> bool:
        """Register a one-shot callback fired (from the eviction
        completion path) once every writeback enqueued SO FAR has
        landed.  Returns False — without registering — when the cache is
        already drained, so the caller can count it complete inline."""
        with self._evict_cond:
            if self._completed >= self._enqueued:
                return False
            self._drain_waiters.append((self._enqueued, cb))
            return True

    # ------------------------------------------------------- write (Alg. 1)
    def write(self, lba: int, data) -> int:
        t_req = time.perf_counter_ns()
        src = np.frombuffer(data, dtype=np.uint8)
        # writes invalidate the clean read tier FIRST (fence in-flight
        # fills), then stage; the eviction writeback re-populates it
        if self.read_tier is not None:
            self.read_tier.invalidate((self.tier_ns, lba))
        while True:
            t0 = time.perf_counter_ns()
            cs = self._set_for(lba)                       # L1: hash -> set
            with cs.lock:                                 # L2-3: probe WBQ set
                sh = cs.table.get(lba)
            self.metrics.add_ns("cache_metadata", time.perf_counter_ns() - t0)
            if sh is not None:
                # ---- write hit (L5-9). Take the slot lock; if the slot was
                # recycled while we waited (eager eviction is fast!), retry.
                with sh.lock:
                    if sh.lba != lba or sh.state not in (VALID, PENDING):
                        continue
                    sh.state = PENDING
                    t1 = time.perf_counter_ns()
                    self._buf[sh.idx, :src.nbytes] = src
                    sh.state = VALID
                    self.metrics.add_ns("cache_write_only",
                                        time.perf_counter_ns() - t1)
                    # enqueue under the slot lock: the evictor cannot observe
                    # the slot between Valid and queued (no recycle window)
                    self._enqueue_for_eviction(cs, sh)
                break
            # ---- write miss.  The volume's global watermark extends the
            # paper's bypass condition: under aggregate staging pressure a
            # write transits straight to BTT even with local slots free.
            globally_full = (self.cfg.conditional_bypass
                             and self.bypass_hook is not None
                             and self.bypass_hook())
            sh = None if globally_full else self._alloc_slot()
            if sh is None:
                if self.cfg.conditional_bypass:
                    # L20-22: cache full -> transit straight to PMem
                    with self.metrics.timer("conditional_bypass"):
                        self.btt.write(lba, src)
                    # second fence: a reader that prepared a fill between
                    # the head-of-write invalidate and this BTT write may
                    # hold the old block — no eviction will fix it, so
                    # invalidate again now the new data is on media
                    if self.read_tier is not None:
                        self.read_tier.invalidate((self.tier_ns, lba))
                    self.metrics.bump("bypass_writes")
                    self.metrics.record_latency(time.perf_counter_ns() - t_req)
                    return 0
                # 'w/o BP' ablation: stall — evict someone on the critical path
                with self.metrics.timer("cache_eviction_and_write"):
                    self._evict_one_sync()
                continue
            with sh.lock:
                sh.lba = lba
                sh.set_idx = _hash_lba(lba) % self.cfg.n_sets
                sh.state = PENDING                         # L14
                # verify no racing miss installed this lba meanwhile (L12-16)
                with cs.lock:
                    other = cs.table.get(lba)
                    if other is not None:
                        # lose the race: return our slot and retry as a hit
                        sh.state = FREE
                        sh.lba = -1
                        self._free.append(sh)
                        continue
                    cs.table[lba] = sh
                t1 = time.perf_counter_ns()
                self._buf[sh.idx, :src.nbytes] = src       # L16
                sh.state = VALID
                self.metrics.add_ns("cache_write_only",
                                    time.perf_counter_ns() - t1)
                self._enqueue_for_eviction(cs, sh)         # L18-19
            break
        self.metrics.record_latency(time.perf_counter_ns() - t_req)
        return 0

    def _enqueue_for_eviction(self, cs: CacheSet, sh: SlotHeader) -> None:
        t0 = time.perf_counter_ns()
        with cs.lock:
            if not sh.queued:
                sh.queued = True
                cs.wbq.append(sh)
                queued = True
            else:
                queued = False
        self.metrics.add_ns("wbq_enqueue", time.perf_counter_ns() - t0)
        if queued and self.cfg.eager_eviction:
            self._notify_eviction(sh)                      # L26

    # --------------------------------------------------------------- read
    def probe(self, lba: int) -> str | None:
        """Non-mutating guess of where a read of ``lba`` would be served
        from ('transit' | 'tier' | None-for-backend) — no hit counters,
        no CLOCK second chance, no scan-detector update.  The volume
        prices tier-aware WFQ read admission with it BEFORE walking the
        stack; a racing write/eviction can invalidate the guess, which
        the post-service settle (``WFQGate.charge``) absorbs."""
        cs = self._set_for(lba)
        with cs.lock:
            sh = cs.table.get(lba)
        if sh is not None and sh.lba == lba \
                and sh.state in (VALID, PENDING, EVICTING):
            return "transit"
        if self.read_tier is not None \
                and (self.tier_ns, lba) in self.read_tier:
            return "tier"
        return None

    def read(self, lba: int, out: np.ndarray | None = None) -> np.ndarray:
        return self.read_ex(lba, out=out)[0]

    def read_ex(self, lba: int, out: np.ndarray | None = None):
        """Read one block and report where it was served from:
        ``(data, source)`` with source 'transit' | 'tier' | 'backend'.
        The volume uses the source for tier-aware QoS pricing (a DRAM
        hit must not debit a tenant like a PMem round trip)."""
        cs = self._set_for(lba)
        with cs.lock:
            sh = cs.table.get(lba)
        if sh is not None:
            with sh.lock:   # waits out Pending writes / in-flight persists
                if sh.lba == lba and sh.state in (VALID, PENDING, EVICTING):
                    self.metrics.bump("read_hits")
                    if out is not None:
                        out[:] = self._buf[sh.idx]
                        return out, "transit"
                    return self._buf[sh.idx].copy(), "transit"
        adm = self.admission
        tier = self.read_tier
        token = None
        fill = False
        if tier is not None:
            key = (self.tier_ns, lba)
            hit = tier.lookup(key, out=out)
            if hit is not None:
                if adm is not None:        # hits still feed the detector
                    adm.observe_read(self.tier_ns, lba)
                self.metrics.bump("read_tier_hits")
                return hit, "tier"
            # sequential-scan bypass: a giant scan's fills would flush
            # the tier's hot set for blocks it never revisits.  One lock
            # round trip: observe + decide together.
            fill = adm is None or adm.observe_and_admit(self.tier_ns, lba)
            if fill:
                token = tier.prepare(key)  # fence the fill against writes
            else:
                self.metrics.bump("tier_fill_bypassed")
        elif adm is not None:
            adm.observe_read(self.tier_ns, lba)
        self.metrics.bump("read_misses")
        data = self.btt.read(lba, out=out)
        if tier is not None and fill and tier.insert(key, data, token=token):
            self.metrics.bump("read_tier_fills")
        return data, "backend"

    # ----------------------------------------------------------- eviction
    def _evict_worker(self) -> None:
        while True:
            sh = self._work.get()
            if sh is None:
                return
            self._evict_slot(sh)
            self._complete_eviction()

    def _evict_slot(self, sh: SlotHeader) -> None:
        """Transit one slot to the device (background thread, Fig. 4 step 5)."""
        with sh.lock:
            cs = self._sets[sh.set_idx] if sh.set_idx >= 0 else None
            if sh.state != VALID or cs is None:
                # recycled or re-claimed; clear queued flag under set lock
                if cs is not None:
                    with cs.lock:
                        sh.queued = False
                        try:
                            cs.wbq.remove(sh)
                        except ValueError:
                            pass
                return
            sh.state = EVICTING
            lba = sh.lba
            # hold the slot lock across the persist: a racing writer/reader of
            # this lba waits for BTT completion (block-level atomicity intact)
            self.btt.write(lba, self._buf[sh.idx])
            if self.read_tier is not None:
                # writeback population: the block leaves the transit cache
                # but stays warm in the clean tier.  Invalidate first so a
                # reader's in-flight stale fill is fenced off, then install
                # the authoritative just-persisted image.
                key = (self.tier_ns, lba)
                self.read_tier.invalidate(key)
                self.read_tier.insert(key, self._buf[sh.idx])
            with cs.lock:
                if cs.table.get(lba) is sh:
                    del cs.table[lba]
                sh.queued = False
                try:
                    cs.wbq.remove(sh)
                except ValueError:
                    pass
            sh.state = FREE
            sh.lba = -1
            sh.set_idx = -1
        self._free.append(sh)
        self.metrics.bump("bg_evictions")

    def _evict_one_sync(self) -> None:
        """'w/o BP' stall path: drain one queued slot on the critical path."""
        for cs in self._sets:
            with cs.lock:
                sh = cs.wbq[0] if cs.wbq else None
            if sh is not None:
                self._evict_slot(sh)
                return
        time.sleep(0)   # nothing queued yet; let background threads run

    # -------------------------------------------------------------- flush
    def kick_drain(self) -> None:
        """Push every queued WBQ entry to the eviction pool NOW — the
        staging-style drain step a flush needs when eager eviction is
        off (with it on, writes already enqueued themselves).  Shared by
        :meth:`flush` and the async frontend's flush tickets, which
        must kick before registering drain waiters or a ``caiti-noee``
        flush ticket would complete with everything still staged."""
        if self.cfg.eager_eviction:
            return
        for cs in self._sets:
            with cs.lock:
                pending = [sh for sh in cs.wbq]
            for sh in pending:
                self._notify_eviction(sh)

    def flush(self, fua: bool = False) -> int:
        """REQ_PREFLUSH handling (§4.4): drain all WBQ entries, wait for BTT.

        Thanks to eager eviction this is almost always a no-op wait.
        """
        with self.metrics.timer("cache_flush"):
            self.kick_drain()
            with self._evict_cond:
                target = self._enqueued
                while self._completed < target:
                    self._evict_cond.wait(timeout=0.5)
            if fua:
                self.btt.flush()   # durable commit (msync for file pools)
        return 0

    def fsync(self) -> int:
        return self.flush(fua=True)

    # ------------------------------------------------------------- stats
    def occupancy(self) -> float:
        return 1.0 - len(self._free) / max(1, len(self._slots))

    def close(self) -> None:
        self.flush(fua=True)
        for _ in self._workers:
            self._work.put(None)
        for w in self._workers:
            w.join(timeout=2.0)
