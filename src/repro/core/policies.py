"""The paper's baseline caching policies — I/O *staging* strategies.

All four intentionally buffer data "for sufficiently long" and pay for it on
the critical path, which is precisely what the paper's motivational study
(§3) demonstrates:

  * **PMBD**     — multi sub-buffers; when a sub-buffer is 100% full the whole
                   sub-buffer is drained *synchronously* before the write.
  * **PMBD-70**  — faithful to the PMBD literature: a *syncer daemon* drains a
                   sub-buffer once it crosses the 70% watermark; the foreground
                   stalls only at 100%.
  * **LRU**      — single pool; on full, evict the least-recently-used slot to
                   BTT and then write into the vacated slot (the 2-step write).
  * **Co-Active**— port of Sun et al. [61]: bloom-filter-based cold/hot
                   separation, dirty & clean lists, and a background thread
                   that *proactively* evicts cold dirty blocks when the device
                   has been idle; on pressure it drops clean blocks first.

They share the interface of :class:`repro.core.cache.CaitiCache` (write /
read / flush / fsync / metrics) so every benchmark treats policies uniformly.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict

import numpy as np

from .btt import BTT
from .metrics import Metrics


class _StagingBase:
    """Common slot pool + bookkeeping for staging policies."""

    def __init__(self, btt: BTT, capacity_bytes: int = 512 << 20,
                 metrics: Metrics | None = None) -> None:
        self.btt = btt
        self.block_size = btt.block_size
        self.n_slots = max(1, capacity_bytes // self.block_size)
        self._buf = np.zeros((self.n_slots, self.block_size), dtype=np.uint8)
        self.metrics = metrics or Metrics()
        self._lock = threading.RLock()
        self._map: dict[int, int] = {}          # lba -> slot idx
        self._owner: list[int] = [-1] * self.n_slots  # slot -> lba
        self._free: list[int] = list(range(self.n_slots))
        self._dirty: set[int] = set()            # slot idxs needing writeback

    # -- helpers ------------------------------------------------------------
    def _writeback(self, slot: int) -> None:
        lba = self._owner[slot]
        if lba >= 0 and slot in self._dirty:
            self.btt.write(lba, self._buf[slot])
            self._dirty.discard(slot)

    def _drop(self, slot: int) -> None:
        lba = self._owner[slot]
        if lba >= 0:
            self._map.pop(lba, None)
        self._owner[slot] = -1
        self._free.append(slot)

    def _install(self, lba: int, src: np.ndarray) -> int:
        slot = self._free.pop()
        self._owner[slot] = lba
        self._map[lba] = slot
        t1 = time.perf_counter_ns()
        self._buf[slot, :src.nbytes] = src
        self.metrics.add_ns("cache_write_only", time.perf_counter_ns() - t1)
        self._dirty.add(slot)
        return slot

    # -- shared read/flush ----------------------------------------------------
    def read(self, lba: int, out: np.ndarray | None = None) -> np.ndarray:
        with self._lock:
            slot = self._map.get(lba)
            if slot is not None:
                self.metrics.bump("read_hits")
                self._touch_read(lba, slot)
                if out is not None:
                    out[:] = self._buf[slot]
                    return out
                return self._buf[slot].copy()
        self.metrics.bump("read_misses")
        return self.btt.read(lba, out=out)

    def _touch_read(self, lba: int, slot: int) -> None:  # LRU override point
        pass

    def flush(self, fua: bool = False) -> int:
        with self.metrics.timer("cache_flush"):
            with self._lock:
                for slot in list(self._dirty):
                    self._writeback(slot)
            if fua:
                self.btt.flush()
        return 0

    def fsync(self) -> int:
        return self.flush(fua=True)

    def occupancy(self) -> float:
        return 1.0 - len(self._free) / self.n_slots

    def close(self) -> None:
        self.flush(fua=True)


class PMBDCache(_StagingBase):
    """PMBD with 100% watermark: full sub-buffer ⇒ synchronous drain."""

    def __init__(self, btt: BTT, capacity_bytes: int = 512 << 20,
                 n_subbuffers: int = 8, watermark: float = 1.0,
                 metrics: Metrics | None = None) -> None:
        super().__init__(btt, capacity_bytes, metrics)
        # every sub-buffer needs at least one slot (tiny test caches)
        self.n_sub = max(1, min(n_subbuffers, self.n_slots))
        self.watermark = watermark
        per = self.n_slots // self.n_sub
        # partition the slot pool into sub-buffers (free lists per sub)
        self._sub_free = [list(range(i * per, (i + 1) * per))
                          for i in range(self.n_sub)]
        self._free = []  # unused; sub-buffers own the slots

    def _sub_for(self, lba: int) -> int:
        return lba % self.n_sub

    def write(self, lba: int, data) -> int:
        t_req = time.perf_counter_ns()
        src = np.frombuffer(data, dtype=np.uint8)
        sub = self._sub_for(lba)
        with self._lock:
            slot = self._map.get(lba)
            if slot is not None:                      # hit: overwrite in place
                t1 = time.perf_counter_ns()
                self._buf[slot, :src.nbytes] = src
                self._dirty.add(slot)
                self.metrics.add_ns("cache_write_only",
                                    time.perf_counter_ns() - t1)
            else:
                if not self._sub_free[sub]:
                    # sub-buffer full: drain it entirely, on the critical path
                    with self.metrics.timer("cache_eviction_and_write"):
                        self._drain_sub(sub)
                self._free = self._sub_free[sub]
                self._install(lba, src)
        self.metrics.record_latency(time.perf_counter_ns() - t_req)
        return 0

    def _drain_sub(self, sub: int) -> None:
        per = self.n_slots // self.n_sub
        for slot in range(sub * per, (sub + 1) * per):
            if self._owner[slot] >= 0:
                self._writeback(slot)
                lba = self._owner[slot]
                self._map.pop(lba, None)
                self._owner[slot] = -1
                self._sub_free[sub].append(slot)


class PMBD70Cache(PMBDCache):
    """PMBD per the literature: syncer daemon drains at the 70% watermark."""

    def __init__(self, btt: BTT, capacity_bytes: int = 512 << 20,
                 n_subbuffers: int = 8, metrics: Metrics | None = None) -> None:
        super().__init__(btt, capacity_bytes, n_subbuffers, watermark=0.7,
                         metrics=metrics)
        self._space = threading.Condition(self._lock)
        self._stop = False
        self._syncer = threading.Thread(target=self._syncer_loop, daemon=True,
                                        name="pmbd70-syncer")
        self._syncer.start()

    def _syncer_loop(self) -> None:
        per = self.n_slots // self.n_sub
        while not self._stop:
            drained = False
            for sub in range(self.n_sub):
                with self._lock:
                    used = per - len(self._sub_free[sub])
                    if used >= self.watermark * per:
                        self._drain_sub(sub)
                        self._space.notify_all()
                        drained = True
            if not drained:
                time.sleep(0.0002)

    def write(self, lba: int, data) -> int:
        t_req = time.perf_counter_ns()
        src = np.frombuffer(data, dtype=np.uint8)
        sub = self._sub_for(lba)
        with self._lock:
            slot = self._map.get(lba)
            if slot is not None:
                t1 = time.perf_counter_ns()
                self._buf[slot, :src.nbytes] = src
                self._dirty.add(slot)
                self.metrics.add_ns("cache_write_only",
                                    time.perf_counter_ns() - t1)
            else:
                # stall only at 100%: wait for the syncer to free space
                t1 = time.perf_counter_ns()
                stalled = False
                while not self._sub_free[sub]:
                    stalled = True
                    self._space.wait(timeout=0.01)
                if stalled:
                    self.metrics.add_ns("cache_eviction_and_write",
                                        time.perf_counter_ns() - t1)
                self._free = self._sub_free[sub]
                self._install(lba, src)
        self.metrics.record_latency(time.perf_counter_ns() - t_req)
        return 0

    def close(self) -> None:
        self._stop = True
        self._syncer.join(timeout=2.0)
        super().close()


class LRUCache(_StagingBase):
    """Classic LRU staging cache: 2-step write on full (paper §3)."""

    def __init__(self, btt: BTT, capacity_bytes: int = 512 << 20,
                 metrics: Metrics | None = None) -> None:
        super().__init__(btt, capacity_bytes, metrics)
        self._lru: OrderedDict[int, int] = OrderedDict()  # lba -> slot

    def _touch_read(self, lba: int, slot: int) -> None:
        self._lru.move_to_end(lba)

    def write(self, lba: int, data) -> int:
        t_req = time.perf_counter_ns()
        src = np.frombuffer(data, dtype=np.uint8)
        with self._lock:
            slot = self._map.get(lba)
            if slot is not None:
                t1 = time.perf_counter_ns()
                self._buf[slot, :src.nbytes] = src
                self._dirty.add(slot)
                self.metrics.add_ns("cache_write_only",
                                    time.perf_counter_ns() - t1)
                self._lru.move_to_end(lba)
            else:
                if not self._free:
                    # 2-step write: evict LRU to PMem, then fill the slot
                    with self.metrics.timer("cache_eviction_and_write"):
                        old_lba, old_slot = self._lru.popitem(last=False)
                        self._writeback(old_slot)
                        self._drop(old_slot)
                self._install(lba, src)
                self._lru[lba] = self._map[lba]
        self.metrics.record_latency(time.perf_counter_ns() - t_req)
        return 0


class CoActiveCache(_StagingBase):
    """Co-Active [61] ported to the PMem block device (as in the paper §5).

    Cold/hot separation via a counting bloom filter (2 B/slot budget in the
    paper); dirty + clean lists; proactive eviction of cold dirty blocks when
    the device is idle; clean blocks are dropped first under pressure.
    """

    _BLOOM_BITS = 16

    def __init__(self, btt: BTT, capacity_bytes: int = 512 << 20,
                 idle_us: float = 200.0, metrics: Metrics | None = None) -> None:
        super().__init__(btt, capacity_bytes, metrics)
        self._bloom = np.zeros(1 << self._BLOOM_BITS, dtype=np.uint8)
        self._dirty_lru: OrderedDict[int, int] = OrderedDict()  # lba -> slot
        self._clean_lru: OrderedDict[int, int] = OrderedDict()
        self._last_io_ns = time.perf_counter_ns()
        self._idle_ns = int(idle_us * 1e3)
        self._stop = False
        self._bg = threading.Thread(target=self._idle_evictor, daemon=True,
                                    name="coactive-bg")
        self._bg.start()

    def _heat(self, lba: int) -> int:
        h = (lba * 0x9E3779B1) & ((1 << self._BLOOM_BITS) - 1)
        return int(self._bloom[h])

    def _warm(self, lba: int) -> None:
        h = (lba * 0x9E3779B1) & ((1 << self._BLOOM_BITS) - 1)
        if self._bloom[h] < 255:
            self._bloom[h] += 1

    def _idle_evictor(self) -> None:
        """Proactively transit cold dirty blocks to PMem while idle."""
        while not self._stop:
            now = time.perf_counter_ns()
            did = False
            if now - self._last_io_ns > self._idle_ns:
                with self._lock:
                    # pick the coldest dirty block (front of LRU, low heat)
                    for lba in list(self._dirty_lru.keys())[:4]:
                        if self._heat(lba) <= 2:
                            slot = self._dirty_lru.pop(lba)
                            self._writeback(slot)
                            self._clean_lru[lba] = slot
                            did = True
                self.metrics.bump("proactive_evictions", 1 if did else 0)
            if not did:
                time.sleep(0.0002)

    def write(self, lba: int, data) -> int:
        t_req = time.perf_counter_ns()
        src = np.frombuffer(data, dtype=np.uint8)
        with self._lock:
            self._last_io_ns = time.perf_counter_ns()
            self._warm(lba)
            slot = self._map.get(lba)
            if slot is not None:
                t1 = time.perf_counter_ns()
                self._buf[slot, :src.nbytes] = src
                self.metrics.add_ns("cache_write_only",
                                    time.perf_counter_ns() - t1)
                self._dirty.add(slot)
                self._clean_lru.pop(lba, None)
                self._dirty_lru[lba] = slot
                self._dirty_lru.move_to_end(lba)
            else:
                if not self._free:
                    self._make_room()
                self._install(lba, src)
                self._dirty_lru[lba] = self._map[lba]
        self.metrics.record_latency(time.perf_counter_ns() - t_req)
        return 0

    def _make_room(self) -> None:
        # prefer dropping a clean block (no I/O); else sync-evict coldest dirty
        if self._clean_lru:
            lba, slot = self._clean_lru.popitem(last=False)
            self._drop(slot)
            return
        with self.metrics.timer("cache_eviction_and_write"):
            lba, slot = self._dirty_lru.popitem(last=False)
            self._writeback(slot)
            self._drop(slot)

    def _touch_read(self, lba: int, slot: int) -> None:
        self._warm(lba)
        if lba in self._dirty_lru:
            self._dirty_lru.move_to_end(lba)
        elif lba in self._clean_lru:
            self._clean_lru.move_to_end(lba)
        self._last_io_ns = time.perf_counter_ns()

    def flush(self, fua: bool = False) -> int:
        with self.metrics.timer("cache_flush"):
            with self._lock:
                # Co-Active's complex list surgery makes its flush expensive
                # (the paper measures 1.9x LRU/PMBD flush time)
                for lba in list(self._dirty_lru.keys()):
                    slot = self._dirty_lru.pop(lba)
                    self._writeback(slot)
                    self._clean_lru[lba] = slot
            if fua:
                self.btt.flush()
        return 0

    def close(self) -> None:
        self._stop = True
        self._bg.join(timeout=2.0)
        super().close()
